//! λFS — the Lambda filesystem (DESIGN.md S4, paper "Backend Media
//! Management", Figure 4b).
//!
//! An EXT4-shaped inode/directory tree laid out over the two NVMe
//! namespaces: the *private* namespace holds ISP internals (`/images`,
//! `/containers`) invisible to the host; the *sharable* namespace holds
//! the in/out data both sides process (`/data`).  File payloads live in
//! flash pages of an [`crate::ssd::SsdDevice`]; every operation charges
//! simulated time through the device's timing model.
//!
//! Concurrency control is the paper's inode-lock protocol: a reference
//! counter per inode, synchronized between host VFS and λFS with special
//! Ether-oN packets (counted, so Figure 11's accounting sees them).

pub mod lock;
pub mod pathwalk;

use std::collections::{BTreeMap, HashMap};

use crate::nvme::namespace::{NamespaceId, PRIVATE_NS, SHARABLE_NS};
use crate::ssd::SsdDevice;
use crate::util::SimTime;

pub use lock::{InodeLockTable, LockSide};
pub use pathwalk::PathWalkCache;

pub type Ino = u64;
pub const ROOT_INO: Ino = 1;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InodeKind {
    File,
    Dir,
}

#[derive(Clone, Debug)]
pub struct Inode {
    pub ino: Ino,
    pub kind: InodeKind,
    pub size: u64,
    pub ns: NamespaceId,
    /// Flash pages backing the file body, in order.
    pub pages: Vec<u64>,
}

/// Result of an operation, carrying the simulated completion time.
#[derive(Debug)]
pub struct FsResult<T> {
    pub value: T,
    pub done: SimTime,
}

#[derive(Debug, PartialEq, Eq)]
pub enum FsError {
    NotFound,
    NotADirectory,
    IsADirectory,
    AlreadyExists,
    Locked,
    CrossNamespace,
}

/// Per-namespace page allocator regions (pages are global device pages).
struct NsAlloc {
    next: u64,
    end: u64,
}

/// The λ filesystem.
pub struct LambdaFs {
    inodes: HashMap<Ino, Inode>,
    dirents: HashMap<Ino, BTreeMap<String, Ino>>,
    next_ino: Ino,
    alloc: HashMap<NamespaceId, NsAlloc>,
    page_bytes: u64,
    pub walk_cache: PathWalkCache,
    pub locks: InodeLockTable,
    /// Stats the models layer consumes.
    pub path_walk_components: u64,
    pub ops: u64,
}

impl LambdaFs {
    /// Create over a device: `private_pages` device pages for the private
    /// namespace starting at page 0, the rest (up to `total_pages`) sharable.
    pub fn new(page_bytes: u64, private_pages: u64, total_pages: u64) -> Self {
        let mut fs = LambdaFs {
            inodes: HashMap::new(),
            dirents: HashMap::new(),
            next_ino: ROOT_INO,
            alloc: HashMap::new(),
            page_bytes,
            walk_cache: PathWalkCache::new(512),
            locks: InodeLockTable::new(),
            path_walk_components: 0,
            ops: 0,
        };
        fs.alloc.insert(
            PRIVATE_NS,
            NsAlloc {
                next: 0,
                end: private_pages,
            },
        );
        fs.alloc.insert(
            SHARABLE_NS,
            NsAlloc {
                next: private_pages,
                end: total_pages,
            },
        );
        let root = fs.mk_inode(InodeKind::Dir, PRIVATE_NS);
        debug_assert_eq!(root, ROOT_INO);
        // canonical layout
        fs.mkdir_p("/images", PRIVATE_NS).unwrap();
        fs.mkdir_p("/images/blobs", PRIVATE_NS).unwrap();
        // content-addressed chunk files of the layerstore: dedup'd image
        // layer + CoW data, invisible to the host like the raw blobs
        fs.mkdir_p("/images/chunks", PRIVATE_NS).unwrap();
        fs.mkdir_p("/images/manifest", PRIVATE_NS).unwrap();
        fs.mkdir_p("/containers", PRIVATE_NS).unwrap();
        fs.mkdir_p("/data", SHARABLE_NS).unwrap();
        fs
    }

    /// Standard sizing from an SsdDevice: 30% private.
    pub fn over_device(dev: &SsdDevice) -> Self {
        let total = dev.cfg.capacity_bytes() / dev.cfg.page_bytes as u64;
        LambdaFs::new(dev.cfg.page_bytes as u64, total * 3 / 10, total)
    }

    fn mk_inode(&mut self, kind: InodeKind, ns: NamespaceId) -> Ino {
        let ino = self.next_ino;
        self.next_ino += 1;
        self.inodes.insert(
            ino,
            Inode {
                ino,
                kind,
                size: 0,
                ns,
                pages: Vec::new(),
            },
        );
        if kind == InodeKind::Dir {
            self.dirents.insert(ino, BTreeMap::new());
        }
        ino
    }

    fn alloc_pages(&mut self, ns: NamespaceId, n: u64) -> Vec<u64> {
        let a = self.alloc.get_mut(&ns).expect("namespace");
        assert!(a.next + n <= a.end, "λFS namespace {ns} out of space");
        let start = a.next;
        a.next += n;
        (start..start + n).collect()
    }

    /// Path walk: resolve `/a/b/c` to an inode, counting component lookups
    /// and consulting the I/O-node cache (paper: "path walking" + "I/O
    /// node caching").
    pub fn walk(&mut self, path: &str) -> Result<Ino, FsError> {
        self.ops += 1;
        if path == "/" {
            return Ok(ROOT_INO);
        }
        if let Some(ino) = self.walk_cache.lookup(path) {
            // cached: one lookup instead of one per component
            self.path_walk_components += 1;
            return Ok(ino);
        }
        let mut cur = ROOT_INO;
        for comp in path.trim_matches('/').split('/') {
            self.path_walk_components += 1;
            let dir = self.dirents.get(&cur).ok_or(FsError::NotADirectory)?;
            cur = *dir.get(comp).ok_or(FsError::NotFound)?;
        }
        self.walk_cache.insert(path, cur);
        Ok(cur)
    }

    fn split_parent(path: &str) -> Result<(&str, &str), FsError> {
        let trimmed = path.trim_end_matches('/');
        let idx = trimmed.rfind('/').ok_or(FsError::NotFound)?;
        let (parent, name) = trimmed.split_at(idx);
        let parent = if parent.is_empty() { "/" } else { parent };
        Ok((parent, &name[1..]))
    }

    /// mkdir -p. Every created directory inherits `ns`.
    pub fn mkdir_p(&mut self, path: &str, ns: NamespaceId) -> Result<Ino, FsError> {
        let mut cur = ROOT_INO;
        for comp in path.trim_matches('/').split('/') {
            self.path_walk_components += 1;
            let existing = self.dirents.get(&cur).and_then(|d| d.get(comp)).copied();
            cur = match existing {
                Some(ino) => {
                    if self.inodes[&ino].kind != InodeKind::Dir {
                        return Err(FsError::NotADirectory);
                    }
                    ino
                }
                None => {
                    let ino = self.mk_inode(InodeKind::Dir, ns);
                    self.dirents.get_mut(&cur).unwrap().insert(comp.into(), ino);
                    ino
                }
            };
        }
        Ok(cur)
    }

    /// Create an empty file; errors if it exists.
    pub fn create(&mut self, path: &str) -> Result<Ino, FsError> {
        let (parent, name) = Self::split_parent(path)?;
        let pino = self.walk(parent)?;
        let pns = self.inodes[&pino].ns;
        if self.inodes[&pino].kind != InodeKind::Dir {
            return Err(FsError::NotADirectory);
        }
        if self.dirents[&pino].contains_key(name) {
            return Err(FsError::AlreadyExists);
        }
        let ino = self.mk_inode(InodeKind::File, pns);
        self.dirents.get_mut(&pino).unwrap().insert(name.into(), ino);
        Ok(ino)
    }

    /// Write a whole file (create if absent), storing bytes in device pages
    /// and charging program time.  `side` must hold access (lock protocol).
    pub fn write_file(
        &mut self,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
        data: &[u8],
        side: LockSide,
    ) -> Result<FsResult<Ino>, FsError> {
        let ino = match self.walk(path) {
            Ok(i) => i,
            Err(FsError::NotFound) => self.create(path)?,
            Err(e) => return Err(e),
        };
        if self.inodes[&ino].kind == InodeKind::Dir {
            return Err(FsError::IsADirectory);
        }
        if !self.locks.may_access(ino, side) {
            return Err(FsError::Locked);
        }
        let npages = (data.len() as u64).div_ceil(self.page_bytes).max(1);
        let (ns, have) = {
            let inode = &self.inodes[&ino];
            (inode.ns, inode.pages.len() as u64)
        };
        if have < npages {
            let extra = self.alloc_pages(ns, npages - have);
            self.inodes.get_mut(&ino).unwrap().pages.extend(extra);
        }
        let inode = self.inodes.get_mut(&ino).unwrap();
        inode.size = data.len() as u64;
        let pages = inode.pages.clone();
        let mut done = at;
        for (i, chunk) in data.chunks(self.page_bytes as usize).enumerate() {
            dev.store_data(pages[i], chunk);
            done = done.max(dev.write_pages(at, pages[i], 1));
        }
        Ok(FsResult { value: ino, done })
    }

    /// Read a whole file, charging read time.
    pub fn read_file(
        &mut self,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
        side: LockSide,
    ) -> Result<FsResult<Vec<u8>>, FsError> {
        let ino = self.walk(path)?;
        let inode = self.inodes.get(&ino).ok_or(FsError::NotFound)?;
        if inode.kind == InodeKind::Dir {
            return Err(FsError::IsADirectory);
        }
        if !self.locks.may_access(ino, side) {
            return Err(FsError::Locked);
        }
        let size = inode.size as usize;
        let pages = inode.pages.clone();
        let mut out = Vec::with_capacity(size);
        let mut done = at;
        for p in &pages {
            done = done.max(dev.read_pages(at, *p, 1));
            out.extend(dev.load_data(*p, self.page_bytes as usize));
        }
        out.truncate(size);
        Ok(FsResult { value: out, done })
    }

    /// Append to a file (used by mini-docker for container logs).
    pub fn append_file(
        &mut self,
        dev: &mut SsdDevice,
        at: SimTime,
        path: &str,
        data: &[u8],
        side: LockSide,
    ) -> Result<FsResult<Ino>, FsError> {
        let existing = match self.walk(path) {
            Ok(_) => self.read_file(dev, at, path, side)?.value,
            Err(FsError::NotFound) => Vec::new(),
            Err(e) => return Err(e),
        };
        let mut all = existing;
        all.extend_from_slice(data);
        self.write_file(dev, at, path, &all, side)
    }

    pub fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        let (parent, name) = Self::split_parent(path)?;
        let pino = self.walk(parent)?;
        let ino = *self
            .dirents
            .get(&pino)
            .and_then(|d| d.get(name))
            .ok_or(FsError::NotFound)?;
        if self.inodes[&ino].kind == InodeKind::Dir && !self.dirents[&ino].is_empty() {
            return Err(FsError::IsADirectory);
        }
        self.dirents.get_mut(&pino).unwrap().remove(name);
        self.inodes.remove(&ino);
        self.dirents.remove(&ino);
        self.walk_cache.invalidate(path);
        Ok(())
    }

    pub fn list(&mut self, path: &str) -> Result<Vec<String>, FsError> {
        let ino = self.walk(path)?;
        let d = self.dirents.get(&ino).ok_or(FsError::NotADirectory)?;
        Ok(d.keys().cloned().collect())
    }

    pub fn stat(&mut self, path: &str) -> Result<Inode, FsError> {
        let ino = self.walk(path)?;
        Ok(self.inodes[&ino].clone())
    }

    /// Is this inode's content visible to the host PCIe function?
    pub fn host_visible(&self, ino: Ino) -> bool {
        self.inodes
            .get(&ino)
            .is_some_and(|i| i.ns == SHARABLE_NS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;

    fn setup() -> (LambdaFs, SsdDevice) {
        let cfg = SsdConfig {
            blocks_per_package: 64,
            ..Default::default()
        };
        let dev = SsdDevice::new(cfg);
        let fs = LambdaFs::over_device(&dev);
        (fs, dev)
    }

    #[test]
    fn canonical_layout_exists() {
        let (mut fs, _) = setup();
        for p in ["/images", "/images/blobs", "/images/chunks", "/containers", "/data"] {
            assert!(fs.walk(p).is_ok(), "{p}");
        }
    }

    #[test]
    fn private_dirs_invisible_to_host() {
        let (mut fs, _) = setup();
        let images = fs.walk("/images").unwrap();
        let data = fs.walk("/data").unwrap();
        assert!(!fs.host_visible(images));
        assert!(fs.host_visible(data));
    }

    #[test]
    fn write_read_round_trip() {
        let (mut fs, mut dev) = setup();
        let body: Vec<u8> = (0..10_000u32).map(|i| (i % 253) as u8).collect();
        let w = fs
            .write_file(&mut dev, SimTime::ZERO, "/data/input.bin", &body, LockSide::Host)
            .unwrap();
        assert!(w.done > SimTime::ZERO, "write must take simulated time");
        let r = fs
            .read_file(&mut dev, w.done, "/data/input.bin", LockSide::Host)
            .unwrap();
        assert_eq!(r.value, body);
    }

    #[test]
    fn overwrite_shrinks_size() {
        let (mut fs, mut dev) = setup();
        fs.write_file(&mut dev, SimTime::ZERO, "/data/f", &[1u8; 9000], LockSide::Host)
            .unwrap();
        fs.write_file(&mut dev, SimTime::ZERO, "/data/f", &[2u8; 10], LockSide::Host)
            .unwrap();
        let r = fs
            .read_file(&mut dev, SimTime::ZERO, "/data/f", LockSide::Host)
            .unwrap();
        assert_eq!(r.value, vec![2u8; 10]);
    }

    #[test]
    fn files_inherit_parent_namespace() {
        let (mut fs, mut dev) = setup();
        fs.write_file(&mut dev, SimTime::ZERO, "/images/blobs/x", b"blob", LockSide::Isp)
            .unwrap();
        let ino = fs.walk("/images/blobs/x").unwrap();
        assert!(!fs.host_visible(ino));
        fs.write_file(&mut dev, SimTime::ZERO, "/data/y", b"data", LockSide::Host)
            .unwrap();
        let ino = fs.walk("/data/y").unwrap();
        assert!(fs.host_visible(ino));
    }

    #[test]
    fn missing_paths_error() {
        let (mut fs, mut dev) = setup();
        assert_eq!(fs.walk("/nope"), Err(FsError::NotFound));
        assert_eq!(
            fs.read_file(&mut dev, SimTime::ZERO, "/data/ghost", LockSide::Host)
                .unwrap_err(),
            FsError::NotFound
        );
    }

    #[test]
    fn create_rejects_duplicates() {
        let (mut fs, _) = setup();
        fs.create("/data/once").unwrap();
        assert_eq!(fs.create("/data/once"), Err(FsError::AlreadyExists));
    }

    #[test]
    fn unlink_removes_and_invalidates_cache() {
        let (mut fs, mut dev) = setup();
        fs.write_file(&mut dev, SimTime::ZERO, "/data/tmp", b"x", LockSide::Host)
            .unwrap();
        assert!(fs.walk("/data/tmp").is_ok());
        fs.unlink("/data/tmp").unwrap();
        assert_eq!(fs.walk("/data/tmp"), Err(FsError::NotFound));
    }

    #[test]
    fn append_accumulates() {
        let (mut fs, mut dev) = setup();
        fs.append_file(&mut dev, SimTime::ZERO, "/containers/log", b"line1\n", LockSide::Isp)
            .unwrap();
        fs.append_file(&mut dev, SimTime::ZERO, "/containers/log", b"line2\n", LockSide::Isp)
            .unwrap();
        let r = fs
            .read_file(&mut dev, SimTime::ZERO, "/containers/log", LockSide::Isp)
            .unwrap();
        assert_eq!(r.value, b"line1\nline2\n".to_vec());
    }

    #[test]
    fn walk_uses_cache_second_time() {
        let (mut fs, mut dev) = setup();
        fs.write_file(&mut dev, SimTime::ZERO, "/data/a", b"1", LockSide::Host)
            .unwrap();
        fs.walk_cache.reset_stats();
        let before = fs.path_walk_components;
        fs.walk("/data/a").unwrap();
        fs.walk("/data/a").unwrap();
        let per_walk = (fs.path_walk_components - before) / 2;
        assert!(per_walk <= 2, "cached walks must be short, got {per_walk}");
        assert!(fs.walk_cache.hits() >= 1);
    }

    #[test]
    fn list_shows_entries_sorted() {
        let (mut fs, _) = setup();
        fs.create("/data/b").unwrap();
        fs.create("/data/a").unwrap();
        assert_eq!(fs.list("/data").unwrap(), vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn lock_blocks_cross_side_access() {
        let (mut fs, mut dev) = setup();
        fs.write_file(&mut dev, SimTime::ZERO, "/data/shared", b"v1", LockSide::Host)
            .unwrap();
        let ino = fs.walk("/data/shared").unwrap();
        // ISP binds the file for processing
        assert!(fs.locks.acquire(ino, LockSide::Isp));
        let denied = fs.write_file(&mut dev, SimTime::ZERO, "/data/shared", b"v2", LockSide::Host);
        assert_eq!(denied.unwrap_err(), FsError::Locked);
        // ISP itself can still write
        assert!(fs
            .write_file(&mut dev, SimTime::ZERO, "/data/shared", b"v2", LockSide::Isp)
            .is_ok());
        fs.locks.release(ino, LockSide::Isp);
        assert!(fs
            .write_file(&mut dev, SimTime::ZERO, "/data/shared", b"v3", LockSide::Host)
            .is_ok());
    }
}
