//! I/O-node cache: path -> inode LRU, the firmware I/O handler's
//! "caches these mappings for faster access" feature.

use std::collections::BTreeMap;

use super::Ino;

/// Bounded LRU of resolved paths.  Sorted map: stamps are unique so the
/// LRU victim never depended on iteration order, but a sorted scan keeps
/// the eviction walk deterministic by construction.
pub struct PathWalkCache {
    map: BTreeMap<String, (Ino, u64)>,
    cap: usize,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PathWalkCache {
    pub fn new(cap: usize) -> Self {
        PathWalkCache {
            map: BTreeMap::new(),
            cap: cap.max(1),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn lookup(&mut self, path: &str) -> Option<Ino> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(path) {
            Some((ino, stamp)) => {
                *stamp = tick;
                self.hits += 1;
                Some(*ino)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, path: &str, ino: Ino) {
        self.tick += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(path) {
            // evict LRU
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
            }
        }
        self.map.insert(path.to_string(), (ino, self.tick));
    }

    pub fn invalidate(&mut self, path: &str) {
        self.map.remove(path);
    }

    pub fn invalidate_all(&mut self) {
        self.map.clear();
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert() {
        let mut c = PathWalkCache::new(8);
        assert_eq!(c.lookup("/a/b"), None);
        c.insert("/a/b", 42);
        assert_eq!(c.lookup("/a/b"), Some(42));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn capacity_bounded_with_lru_eviction() {
        let mut c = PathWalkCache::new(3);
        c.insert("/a", 1);
        c.insert("/b", 2);
        c.insert("/c", 3);
        c.lookup("/a"); // refresh /a
        c.insert("/d", 4); // evicts /b (LRU)
        assert_eq!(c.len(), 3);
        assert_eq!(c.lookup("/b"), None);
        assert_eq!(c.lookup("/a"), Some(1));
        assert_eq!(c.lookup("/d"), Some(4));
    }

    #[test]
    fn invalidate_removes() {
        let mut c = PathWalkCache::new(4);
        c.insert("/x", 9);
        c.invalidate("/x");
        assert_eq!(c.lookup("/x"), None);
    }

    #[test]
    fn reinsert_updates_value() {
        let mut c = PathWalkCache::new(4);
        c.insert("/x", 1);
        c.insert("/x", 2);
        assert_eq!(c.lookup("/x"), Some(2));
        assert_eq!(c.len(), 1);
    }
}
