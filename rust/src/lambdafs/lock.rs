//! The inode lock: λFS's host/ISP concurrency-control protocol.
//!
//! From the paper: "λFS adds a reference counter to the inode ... This
//! counter updates when the target file (or its directory file) is opened
//! or closed.  VFS and λFS then send a special packet via Ether-oN to
//! update it.  The file is accessible only if the inode reference counter
//! [of the other side] is zero."  On ISP acquisition the host VFS
//! invalidates its inode cache.  The lock is non-persistent by design
//! (power loss resets it; the host restores the FS and restarts the
//! container).

use std::collections::HashMap;

use super::Ino;

/// Which side of the PCIe boundary is asking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockSide {
    Host,
    Isp,
}

impl LockSide {
    pub fn other(self) -> LockSide {
        match self {
            LockSide::Host => LockSide::Isp,
            LockSide::Isp => LockSide::Host,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct RefCounts {
    host: u32,
    isp: u32,
}

/// Per-inode reference counters plus the Ether-oN sync accounting.
#[derive(Debug, Default)]
pub struct InodeLockTable {
    refs: HashMap<Ino, RefCounts>,
    /// Special sync packets exchanged over Ether-oN (counted for Fig 11).
    pub sync_packets: u64,
    /// Host VFS inode-cache invalidations triggered by ISP acquisition.
    pub vfs_invalidations: u64,
}

impl InodeLockTable {
    pub fn new() -> Self {
        Self::default()
    }

    fn counts(&self, ino: Ino) -> RefCounts {
        self.refs.get(&ino).copied().unwrap_or_default()
    }

    /// May `side` access `ino` right now?  Allowed iff the *other* side's
    /// reference counter is zero.
    pub fn may_access(&self, ino: Ino, side: LockSide) -> bool {
        let c = self.counts(ino);
        match side {
            LockSide::Host => c.isp == 0,
            LockSide::Isp => c.host == 0,
        }
    }

    /// Open/bind: increment `side`'s counter.  Fails (no change) when the
    /// other side currently holds the inode.
    pub fn acquire(&mut self, ino: Ino, side: LockSide) -> bool {
        if !self.may_access(ino, side) {
            return false;
        }
        let entry = self.refs.entry(ino).or_default();
        match side {
            LockSide::Host => entry.host += 1,
            LockSide::Isp => {
                entry.isp += 1;
                // "VFS invalidates its inode cache, referring to the
                // storage's latest information"
                self.vfs_invalidations += 1;
            }
        }
        // counter update crosses Ether-oN as a special packet
        self.sync_packets += 1;
        true
    }

    /// Close/unbind: decrement `side`'s counter (saturating).
    pub fn release(&mut self, ino: Ino, side: LockSide) {
        if let Some(entry) = self.refs.get_mut(&ino) {
            match side {
                LockSide::Host => entry.host = entry.host.saturating_sub(1),
                LockSide::Isp => entry.isp = entry.isp.saturating_sub(1),
            }
            self.sync_packets += 1;
            if entry.host == 0 && entry.isp == 0 {
                self.refs.remove(&ino);
            }
        }
    }

    /// Power-failure semantics: all locks vanish (non-persistent).
    pub fn reset(&mut self) {
        self.refs.clear();
    }

    pub fn held(&self, ino: Ino) -> bool {
        let c = self.counts(ino);
        c.host > 0 || c.isp > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_sides_free_initially() {
        let t = InodeLockTable::new();
        assert!(t.may_access(1, LockSide::Host));
        assert!(t.may_access(1, LockSide::Isp));
    }

    #[test]
    fn isp_bind_excludes_host() {
        let mut t = InodeLockTable::new();
        assert!(t.acquire(1, LockSide::Isp));
        assert!(!t.may_access(1, LockSide::Host));
        assert!(t.may_access(1, LockSide::Isp)); // same side re-enters
        t.release(1, LockSide::Isp);
        assert!(t.may_access(1, LockSide::Host));
    }

    #[test]
    fn nested_opens_require_matching_closes() {
        let mut t = InodeLockTable::new();
        assert!(t.acquire(1, LockSide::Host));
        assert!(t.acquire(1, LockSide::Host));
        t.release(1, LockSide::Host);
        assert!(!t.may_access(1, LockSide::Isp), "still one host ref");
        t.release(1, LockSide::Host);
        assert!(t.may_access(1, LockSide::Isp));
    }

    #[test]
    fn cross_acquire_fails_without_sideeffect() {
        let mut t = InodeLockTable::new();
        t.acquire(1, LockSide::Host);
        let packets_before = t.sync_packets;
        assert!(!t.acquire(1, LockSide::Isp));
        assert_eq!(t.sync_packets, packets_before, "failed acquire sends nothing");
    }

    #[test]
    fn isp_acquire_invalidates_host_vfs_cache() {
        let mut t = InodeLockTable::new();
        t.acquire(7, LockSide::Isp);
        assert_eq!(t.vfs_invalidations, 1);
        t.acquire(8, LockSide::Host);
        assert_eq!(t.vfs_invalidations, 1, "host acquire does not invalidate");
    }

    #[test]
    fn sync_packets_counted_per_update() {
        let mut t = InodeLockTable::new();
        t.acquire(1, LockSide::Host);
        t.release(1, LockSide::Host);
        assert_eq!(t.sync_packets, 2);
    }

    #[test]
    fn power_failure_resets_locks() {
        let mut t = InodeLockTable::new();
        t.acquire(1, LockSide::Isp);
        t.acquire(2, LockSide::Host);
        t.reset();
        assert!(!t.held(1));
        assert!(!t.held(2));
        assert!(t.may_access(1, LockSide::Host));
    }

    #[test]
    fn independent_inodes_do_not_interfere() {
        let mut t = InodeLockTable::new();
        t.acquire(1, LockSide::Isp);
        assert!(t.may_access(2, LockSide::Host));
        assert!(t.acquire(2, LockSide::Host));
    }

    #[test]
    fn release_without_acquire_is_safe() {
        let mut t = InodeLockTable::new();
        t.release(99, LockSide::Host); // no panic
        assert!(!t.held(99));
    }
}
