//! Configuration system: every tunable of the simulated testbed in one
//! JSON-loadable tree, mirroring the paper's evaluation setup
//! ("EVALUATION — Prototype and methodology").
//!
//! Defaults reproduce the paper's testbed: host 3.8GHz CPU + 64GB DDR4;
//! SSD frontend 2.2GHz + 2GB DRAM; backend 48 MLC flash packages over 12
//! channels; pool of 16-128 DockerSSDs behind PCIe switches.
//!
//! (Offline-build substitution, DESIGN.md §4: serde/toml are unavailable,
//! so configs are JSON via the in-crate [`crate::json`] module; any field
//! omitted in a config file keeps its paper default.)

use crate::json::{parse, Json};

/// Host system parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct HostConfig {
    /// Host CPU frequency (GHz) — 3.8 in the paper.
    pub cpu_ghz: f64,
    /// Host DRAM capacity (GiB).
    pub dram_gib: u64,
    /// Host DRAM bandwidth (GB/s).
    pub dram_gbps: f64,
    /// PCIe link bandwidth to the SSD (GB/s, Gen3 x4 effective).
    pub pcie_gbps: f64,
}

impl Default for HostConfig {
    fn default() -> Self {
        HostConfig {
            cpu_ghz: 3.8,
            dram_gib: 64,
            dram_gbps: 25.6,
            pcie_gbps: 3.2,
        }
    }
}

/// SSD geometry + timing (SimpleSSD-style MLC parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct SsdConfig {
    /// Frontend embedded processor frequency (GHz) — 2.2 in the paper.
    pub frontend_ghz: f64,
    /// Frontend cores running Virtual-FW — 6 RISC-V cores in the prototype.
    pub frontend_cores: u32,
    /// Internal DRAM capacity (GiB) — 2 in the paper.
    pub dram_gib: u64,
    /// Flash channels — 12 in the paper.
    pub channels: u32,
    /// Packages per channel (48 total / 12 channels).
    pub packages_per_channel: u32,
    /// Flash page size (bytes).
    pub page_bytes: u32,
    /// Pages per block.
    pub pages_per_block: u32,
    /// Blocks per package.
    pub blocks_per_package: u32,
    /// MLC page read latency (us).
    pub read_us: u64,
    /// MLC page program latency (us).
    pub program_us: u64,
    /// Block erase latency (us).
    pub erase_us: u64,
    /// Channel transfer rate (MB/s per channel, ONFI-class).
    pub channel_mbps: f64,
    /// ICL (internal cache layer) size as a fraction of internal DRAM.
    pub icl_fraction: f64,
    /// Over-provisioning fraction reserved for GC.
    pub op_fraction: f64,
    /// GC trigger: free-block fraction below which GC runs.
    pub gc_threshold: f64,
}

impl Default for SsdConfig {
    fn default() -> Self {
        SsdConfig {
            frontend_ghz: 2.2,
            frontend_cores: 6,
            dram_gib: 2,
            channels: 12,
            packages_per_channel: 4,
            page_bytes: 4096,
            pages_per_block: 256,
            blocks_per_package: 2048,
            read_us: 50,
            program_us: 500,
            erase_us: 3500,
            channel_mbps: 400.0,
            icl_fraction: 0.5,
            op_fraction: 0.07,
            gc_threshold: 0.05,
        }
    }
}

impl SsdConfig {
    pub fn total_packages(&self) -> u32 {
        self.channels * self.packages_per_channel
    }
    pub fn pages_per_package(&self) -> u64 {
        self.pages_per_block as u64 * self.blocks_per_package as u64
    }
    pub fn capacity_bytes(&self) -> u64 {
        self.total_packages() as u64 * self.pages_per_package() * self.page_bytes as u64
    }
}

/// Ether-oN interface parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct EtherOnConfig {
    /// Pre-allocated receive-frame upcall commands per SQ (paper: 4).
    pub upcalls_per_sq: u32,
    /// NVMe queue depth per SQ/CQ pair.
    pub queue_depth: u32,
    /// Frame page size — sk_buff copied into a 4KB-aligned kernel page.
    pub frame_page_bytes: u32,
    /// MTU for the virtual adapter.
    pub mtu: u32,
}

impl Default for EtherOnConfig {
    fn default() -> Self {
        EtherOnConfig {
            upcalls_per_sq: 4,
            queue_depth: 64,
            frame_page_bytes: 4096,
            mtu: 1500,
        }
    }
}

/// Storage-pool / disaggregation parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolConfig {
    /// DockerSSDs per array (behind one PCIe switch).
    pub nodes_per_array: u32,
    /// Number of arrays in the cluster.
    pub arrays: u32,
    /// Per-hop PCIe switch latency (ns).
    pub switch_hop_ns: u64,
    /// Intra-array link bandwidth (GB/s).
    pub link_gbps: f64,
    /// Cross-array switch-tray backplane bandwidth (GB/s).
    pub tray_gbps: f64,
    /// Host uplink bandwidth into the tray (GB/s).
    pub host_gbps: f64,
    /// Registry WAN bandwidth beyond the host (GB/s) — the paper's
    /// "user-defined location"; default is 1/8 of the intranet link.
    pub wan_gbps: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            nodes_per_array: 16,
            arrays: 1,
            switch_hop_ns: 300,
            link_gbps: 3.2,
            tray_gbps: 3.2,
            host_gbps: 3.2,
            wan_gbps: 0.4,
        }
    }
}

impl PoolConfig {
    pub fn total_nodes(&self) -> u32 {
        self.nodes_per_array * self.arrays
    }
}

/// Serving coordinator parameters (the E9 case study).  The loop runs
/// on the pool's simulated clock, so every duration here is simulated
/// time, not wallclock.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Artifact directory with HLO text + weights.
    pub artifacts_dir: String,
    /// Max new tokens per request.
    pub max_new_tokens: u32,
    /// Number of pool nodes to serve from.
    pub nodes: u32,
    /// Batch window before a partial batch launches (simulated us).
    pub batch_timeout_us: u64,
    /// Engine batch width the batcher packs to.
    pub batch_width: u32,
    /// Engine prompt length requests are fit to.
    pub prompt_len: u32,
    /// Simulated prefill compute per batch (us).
    pub prefill_compute_us: u64,
    /// Simulated decode compute per generated token (us).
    pub token_compute_us: u64,
    /// Per-node KV capacity in MiB; 0 means unbounded.
    pub kv_capacity_mib: u64,
    /// Table 2 row to replay as the arrival process (e.g.
    /// "mariadb-tpch4"); empty means a uniform-random storm.
    pub workload: String,
    /// Trace scale factor for `workload` replays (ops = counts / scale).
    pub trace_scale: u64,
    /// Replicas to boot on the shared clock while serving; 0 disables
    /// the serve-while-deploy experiment.
    pub boot_storm: u32,
    /// LLM whose geometry sizes per-token KV (an `llm::all_llms` name);
    /// empty means the default synthetic per-token footprint.
    pub kv_model: String,
    /// Which bytes ride which links: "streamed" (default) or "hairpin"
    /// (the pre-stream baseline shape).
    pub wire: String,
    /// Echo generated tokens to stdout.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            max_new_tokens: 32,
            nodes: 2,
            batch_timeout_us: 2000,
            batch_width: 4,
            prompt_len: 32,
            prefill_compute_us: 500,
            token_compute_us: 50,
            kv_capacity_mib: 0,
            workload: String::new(),
            trace_scale: 10_000,
            boot_storm: 0,
            kv_model: String::new(),
            wire: "streamed".into(),
            verbose: true,
        }
    }
}

impl ServeConfig {
    /// Start a serve config for one Table 2 trace row (or `""` for the
    /// uniform-random storm) and tune it with the consuming builder:
    ///
    /// ```
    /// use dockerssd::config::ServeConfig;
    /// let c = ServeConfig::for_workload("rocksdb-write")
    ///     .batch_width(8)
    ///     .nodes(4)
    ///     .wire("streamed");
    /// assert_eq!(c.workload, "rocksdb-write");
    /// assert_eq!(c.batch_width, 8);
    /// ```
    ///
    /// Every field stays `pub`; the builder is sugar over struct-update
    /// syntax, not an encapsulation layer.
    pub fn for_workload(row: impl Into<String>) -> Self {
        ServeConfig { workload: row.into(), ..Default::default() }
    }

    /// Engine batch width the batcher packs to (clamped to >= 1 at use).
    pub fn batch_width(mut self, w: u32) -> Self {
        self.batch_width = w;
        self
    }

    /// Number of pool nodes to serve from.
    pub fn nodes(mut self, n: u32) -> Self {
        self.nodes = n;
        self
    }

    /// Per-node KV capacity in MiB; 0 means unbounded.
    pub fn kv_capacity_mib(mut self, mib: u64) -> Self {
        self.kv_capacity_mib = mib;
        self
    }

    /// Replicas to boot on the shared clock while serving.
    pub fn boot_storm(mut self, replicas: u32) -> Self {
        self.boot_storm = replicas;
        self
    }

    /// Trace scale factor for workload replays (ops = counts / scale).
    pub fn trace_scale(mut self, scale: u64) -> Self {
        self.trace_scale = scale;
        self
    }

    /// LLM whose geometry sizes per-token KV.
    pub fn kv_model(mut self, model: impl Into<String>) -> Self {
        self.kv_model = model.into();
        self
    }

    /// Wire policy name: "streamed" or "hairpin".
    pub fn wire(mut self, policy: impl Into<String>) -> Self {
        self.wire = policy.into();
        self
    }

    /// Batch window before a partial batch launches (simulated us).
    pub fn batch_timeout_us(mut self, us: u64) -> Self {
        self.batch_timeout_us = us;
        self
    }

    /// Max new tokens per request.
    pub fn max_new_tokens(mut self, n: u32) -> Self {
        self.max_new_tokens = n;
        self
    }

    /// Echo generated tokens to stdout.
    pub fn verbose(mut self, on: bool) -> Self {
        self.verbose = on;
        self
    }
}

/// Top-level config tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SystemConfig {
    pub host: HostConfig,
    pub ssd: SsdConfig,
    pub etheron: EtherOnConfig,
    pub pool: PoolConfig,
    pub serve: ServeConfig,
}

// --- JSON (de)serialization ------------------------------------------------

macro_rules! get_field {
    ($obj:expr, $cfg:expr, $field:ident, f64) => {
        if let Some(v) = $obj.get(stringify!($field)).and_then(Json::as_f64) {
            $cfg.$field = v;
        }
    };
    ($obj:expr, $cfg:expr, $field:ident, u64) => {
        if let Some(v) = $obj.get(stringify!($field)).and_then(Json::as_u64) {
            $cfg.$field = v;
        }
    };
    ($obj:expr, $cfg:expr, $field:ident, u32) => {
        if let Some(v) = $obj.get(stringify!($field)).and_then(Json::as_u64) {
            $cfg.$field = v as u32;
        }
    };
    ($obj:expr, $cfg:expr, $field:ident, bool) => {
        if let Some(v) = $obj.get(stringify!($field)).and_then(Json::as_bool) {
            $cfg.$field = v;
        }
    };
    ($obj:expr, $cfg:expr, $field:ident, String) => {
        if let Some(v) = $obj.get(stringify!($field)).and_then(Json::as_str) {
            $cfg.$field = v.to_string();
        }
    };
}

impl SystemConfig {
    /// Load from a JSON file; missing sections/fields keep paper defaults.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    }

    pub fn from_json_str(text: &str) -> Result<Self, String> {
        let root = parse(text)?;
        let mut cfg = SystemConfig::default();
        if let Some(h) = root.get("host") {
            get_field!(h, cfg.host, cpu_ghz, f64);
            get_field!(h, cfg.host, dram_gib, u64);
            get_field!(h, cfg.host, dram_gbps, f64);
            get_field!(h, cfg.host, pcie_gbps, f64);
        }
        if let Some(s) = root.get("ssd") {
            get_field!(s, cfg.ssd, frontend_ghz, f64);
            get_field!(s, cfg.ssd, frontend_cores, u32);
            get_field!(s, cfg.ssd, dram_gib, u64);
            get_field!(s, cfg.ssd, channels, u32);
            get_field!(s, cfg.ssd, packages_per_channel, u32);
            get_field!(s, cfg.ssd, page_bytes, u32);
            get_field!(s, cfg.ssd, pages_per_block, u32);
            get_field!(s, cfg.ssd, blocks_per_package, u32);
            get_field!(s, cfg.ssd, read_us, u64);
            get_field!(s, cfg.ssd, program_us, u64);
            get_field!(s, cfg.ssd, erase_us, u64);
            get_field!(s, cfg.ssd, channel_mbps, f64);
            get_field!(s, cfg.ssd, icl_fraction, f64);
            get_field!(s, cfg.ssd, op_fraction, f64);
            get_field!(s, cfg.ssd, gc_threshold, f64);
        }
        if let Some(e) = root.get("etheron") {
            get_field!(e, cfg.etheron, upcalls_per_sq, u32);
            get_field!(e, cfg.etheron, queue_depth, u32);
            get_field!(e, cfg.etheron, frame_page_bytes, u32);
            get_field!(e, cfg.etheron, mtu, u32);
        }
        if let Some(p) = root.get("pool") {
            get_field!(p, cfg.pool, nodes_per_array, u32);
            get_field!(p, cfg.pool, arrays, u32);
            get_field!(p, cfg.pool, switch_hop_ns, u64);
            get_field!(p, cfg.pool, link_gbps, f64);
            get_field!(p, cfg.pool, tray_gbps, f64);
            get_field!(p, cfg.pool, host_gbps, f64);
            get_field!(p, cfg.pool, wan_gbps, f64);
        }
        if let Some(s) = root.get("serve") {
            get_field!(s, cfg.serve, artifacts_dir, String);
            get_field!(s, cfg.serve, max_new_tokens, u32);
            get_field!(s, cfg.serve, nodes, u32);
            get_field!(s, cfg.serve, batch_timeout_us, u64);
            get_field!(s, cfg.serve, batch_width, u32);
            get_field!(s, cfg.serve, prompt_len, u32);
            get_field!(s, cfg.serve, prefill_compute_us, u64);
            get_field!(s, cfg.serve, token_compute_us, u64);
            get_field!(s, cfg.serve, kv_capacity_mib, u64);
            get_field!(s, cfg.serve, workload, String);
            get_field!(s, cfg.serve, trace_scale, u64);
            get_field!(s, cfg.serve, boot_storm, u32);
            get_field!(s, cfg.serve, kv_model, String);
            get_field!(s, cfg.serve, wire, String);
            get_field!(s, cfg.serve, verbose, bool);
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "host",
                Json::obj(vec![
                    ("cpu_ghz", Json::Num(self.host.cpu_ghz)),
                    ("dram_gib", Json::Int(self.host.dram_gib as i64)),
                    ("dram_gbps", Json::Num(self.host.dram_gbps)),
                    ("pcie_gbps", Json::Num(self.host.pcie_gbps)),
                ]),
            ),
            (
                "ssd",
                Json::obj(vec![
                    ("frontend_ghz", Json::Num(self.ssd.frontend_ghz)),
                    ("frontend_cores", Json::Int(self.ssd.frontend_cores as i64)),
                    ("dram_gib", Json::Int(self.ssd.dram_gib as i64)),
                    ("channels", Json::Int(self.ssd.channels as i64)),
                    (
                        "packages_per_channel",
                        Json::Int(self.ssd.packages_per_channel as i64),
                    ),
                    ("page_bytes", Json::Int(self.ssd.page_bytes as i64)),
                    ("pages_per_block", Json::Int(self.ssd.pages_per_block as i64)),
                    (
                        "blocks_per_package",
                        Json::Int(self.ssd.blocks_per_package as i64),
                    ),
                    ("read_us", Json::Int(self.ssd.read_us as i64)),
                    ("program_us", Json::Int(self.ssd.program_us as i64)),
                    ("erase_us", Json::Int(self.ssd.erase_us as i64)),
                    ("channel_mbps", Json::Num(self.ssd.channel_mbps)),
                    ("icl_fraction", Json::Num(self.ssd.icl_fraction)),
                    ("op_fraction", Json::Num(self.ssd.op_fraction)),
                    ("gc_threshold", Json::Num(self.ssd.gc_threshold)),
                ]),
            ),
            (
                "etheron",
                Json::obj(vec![
                    ("upcalls_per_sq", Json::Int(self.etheron.upcalls_per_sq as i64)),
                    ("queue_depth", Json::Int(self.etheron.queue_depth as i64)),
                    (
                        "frame_page_bytes",
                        Json::Int(self.etheron.frame_page_bytes as i64),
                    ),
                    ("mtu", Json::Int(self.etheron.mtu as i64)),
                ]),
            ),
            (
                "pool",
                Json::obj(vec![
                    ("nodes_per_array", Json::Int(self.pool.nodes_per_array as i64)),
                    ("arrays", Json::Int(self.pool.arrays as i64)),
                    ("switch_hop_ns", Json::Int(self.pool.switch_hop_ns as i64)),
                    ("link_gbps", Json::Num(self.pool.link_gbps)),
                    ("tray_gbps", Json::Num(self.pool.tray_gbps)),
                    ("host_gbps", Json::Num(self.pool.host_gbps)),
                    ("wan_gbps", Json::Num(self.pool.wan_gbps)),
                ]),
            ),
            (
                "serve",
                Json::obj(vec![
                    ("artifacts_dir", Json::str(self.serve.artifacts_dir.clone())),
                    ("max_new_tokens", Json::Int(self.serve.max_new_tokens as i64)),
                    ("nodes", Json::Int(self.serve.nodes as i64)),
                    ("batch_timeout_us", Json::Int(self.serve.batch_timeout_us as i64)),
                    ("batch_width", Json::Int(self.serve.batch_width as i64)),
                    ("prompt_len", Json::Int(self.serve.prompt_len as i64)),
                    (
                        "prefill_compute_us",
                        Json::Int(self.serve.prefill_compute_us as i64),
                    ),
                    ("token_compute_us", Json::Int(self.serve.token_compute_us as i64)),
                    ("kv_capacity_mib", Json::Int(self.serve.kv_capacity_mib as i64)),
                    ("workload", Json::str(self.serve.workload.clone())),
                    ("trace_scale", Json::Int(self.serve.trace_scale as i64)),
                    ("boot_storm", Json::Int(self.serve.boot_storm as i64)),
                    ("kv_model", Json::str(self.serve.kv_model.clone())),
                    ("wire", Json::str(self.serve.wire.clone())),
                    ("verbose", Json::Bool(self.serve.verbose)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = SystemConfig::default();
        assert_eq!(c.host.cpu_ghz, 3.8);
        assert_eq!(c.ssd.frontend_ghz, 2.2);
        assert_eq!(c.ssd.channels, 12);
        assert_eq!(c.ssd.total_packages(), 48);
        assert_eq!(c.etheron.upcalls_per_sq, 4);
        assert_eq!(c.pool.total_nodes(), 16);
    }

    #[test]
    fn ssd_capacity_is_reasonable() {
        let c = SsdConfig::default();
        let gb = c.capacity_bytes() as f64 / 1e9;
        assert!(gb > 90.0, "capacity {gb}GB");
    }

    #[test]
    fn json_round_trip() {
        let c = SystemConfig::default();
        let text = c.to_json().dump();
        let back = SystemConfig::from_json_str(&text).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let back = SystemConfig::from_json_str(r#"{"host": {"cpu_ghz": 4.2}}"#).unwrap();
        assert_eq!(back.host.cpu_ghz, 4.2);
        assert_eq!(back.host.dram_gib, 64); // default field
        assert_eq!(back.ssd.channels, 12); // default section
    }

    #[test]
    fn bad_json_is_an_error() {
        assert!(SystemConfig::from_json_str("{nope").is_err());
    }

    #[test]
    fn serve_config_simulated_fields_load() {
        let c = SystemConfig::from_json_str(
            r#"{"serve": {"batch_width": 8, "token_compute_us": 75, "kv_capacity_mib": 256}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.batch_width, 8);
        assert_eq!(c.serve.token_compute_us, 75);
        assert_eq!(c.serve.kv_capacity_mib, 256);
        assert_eq!(c.serve.prompt_len, 32, "untouched fields keep defaults");
    }

    #[test]
    fn builder_matches_struct_literal() {
        let built = ServeConfig::for_workload("rocksdb-write")
            .batch_width(8)
            .nodes(4)
            .kv_capacity_mib(256)
            .boot_storm(2)
            .trace_scale(5000)
            .kv_model("lamda-137B")
            .wire("hairpin")
            .batch_timeout_us(1500)
            .max_new_tokens(16)
            .verbose(false);
        let literal = ServeConfig {
            workload: "rocksdb-write".into(),
            batch_width: 8,
            nodes: 4,
            kv_capacity_mib: 256,
            boot_storm: 2,
            trace_scale: 5000,
            kv_model: "lamda-137B".into(),
            wire: "hairpin".into(),
            batch_timeout_us: 1500,
            max_new_tokens: 16,
            verbose: false,
            ..Default::default()
        };
        assert_eq!(built, literal, "builder is sugar, not a second code path");
        assert_eq!(ServeConfig::default().wire, "streamed");
    }

    #[test]
    fn serve_wire_field_loads() {
        let c = SystemConfig::from_json_str(r#"{"serve": {"wire": "hairpin"}}"#).unwrap();
        assert_eq!(c.serve.wire, "hairpin");
        assert_eq!(SystemConfig::default().serve.wire, "streamed");
    }

    #[test]
    fn serve_config_trace_fields_load() {
        let c = SystemConfig::from_json_str(
            r#"{"serve": {"workload": "nginx-filedown", "trace_scale": 2000,
                          "boot_storm": 4, "kv_model": "lamda-137B"}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.workload, "nginx-filedown");
        assert_eq!(c.serve.trace_scale, 2000);
        assert_eq!(c.serve.boot_storm, 4);
        assert_eq!(c.serve.kv_model, "lamda-137B");
        let d = SystemConfig::default();
        assert!(d.serve.workload.is_empty(), "default is the uniform storm");
        assert_eq!(d.serve.boot_storm, 0);
    }
}
