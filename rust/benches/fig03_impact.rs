//! Bench E1 — Figure 3: Host vs P.ISP breakdown over all 13 workloads.
//! Prints the figure's rows and measures the model-evaluation hot path.

use dockerssd::benchkit::{bench, section};
use dockerssd::firmware::CostModel;
use dockerssd::models::{evaluate, Component, ModelKind};
use dockerssd::workloads::all_workloads;

fn main() {
    let c = CostModel::calibrated();
    let ws = all_workloads();

    section("Figure 3: Host vs P.ISP breakdown");
    println!(
        "{:<16} {:>10} {:>10} {:>12} | {:>10} {:>12} {:>12}",
        "workload", "Host(s)", "Storage%", "Compute%", "P.ISP(s)", "Communicate%", "Storage%"
    );
    let (mut sf, mut cf, mut ratio) = (0.0, 0.0, 0.0);
    for w in &ws {
        let h = evaluate(ModelKind::Host, w, &c);
        let p = evaluate(ModelKind::PIspR, w, &c);
        println!(
            "{:<16} {:>10.2} {:>9.0}% {:>11.0}% | {:>10.2} {:>11.0}% {:>11.0}%",
            w.full_name(),
            h.total(),
            100.0 * h.fraction(Component::Storage),
            100.0 * h.fraction(Component::Compute),
            p.total(),
            100.0 * p.communicate() / p.total(),
            100.0 * p.fraction(Component::Storage),
        );
        sf += h.fraction(Component::Storage);
        cf += p.communicate() / p.total();
        ratio += p.total() / h.total();
    }
    let n = ws.len() as f64;
    println!(
        "\nmeans: Host Storage {:.0}% (paper 38%) | P.ISP Communicate {:.0}% (paper 43%) | P.ISP/Host {:.2}x (paper 1.4x)",
        100.0 * sf / n,
        100.0 * cf / n,
        ratio / n
    );

    section("hot path");
    bench("evaluate all 13 workloads x 2 models", || {
        for w in &ws {
            std::hint::black_box(evaluate(ModelKind::Host, w, &c));
            std::hint::black_box(evaluate(ModelKind::PIspR, w, &c));
        }
    });
}
