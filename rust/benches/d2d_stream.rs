//! Device-to-device KV streaming A/B: Table 2 rows served under the
//! pre-stream hairpin wire policy and the streamed policy, on identical
//! clocks and request streams — the fig12/13 host-traffic extension.
//!
//! Emits `BENCH_d2d_stream.json` ({name, metric, value}) records:
//!
//! * invariant metrics the committed baselines gate now —
//!   `host_uplink_reduction_visible` (the pinned LLM-serving rows cut
//!   host-uplink bytes per served token by >= 3x) and
//!   `same_seed_identical` (two same-seed streamed replays are
//!   byte-identical) are 1.0 by construction and regress to 0.0 only
//!   when the property breaks;
//! * simulation-shape metrics (`host_bytes_per_token_*`,
//!   `uplink_reduction`, `handoff_speedup`) — deterministic and
//!   machine-independent, reported as new benches until committed.
//!
//! rocksdb-write is reported but not pinned: its prompts carry the full
//! write payload, genuine ingress no wire policy can remove.

use dockerssd::benchkit::{emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig};
use dockerssd::coordinator::{serve, EchoExecutor, ServeParams, ServeReport, WirePolicy};
use dockerssd::fabric::Fabric;
use dockerssd::llm::disagg::{handoff_traffic, stream_handoffs};
use dockerssd::llm::{all_llms, Parallelism};
use dockerssd::metrics::{names, Counters, Table};
use dockerssd::sim::PoolSim;
use dockerssd::util::SimTime;
use dockerssd::workloads::{trace_arrivals, workload_named, ArrivalParams};

/// Rows whose >= 3x uplink reduction the invariant metric gates — the
/// same rows the tier-1 test `streamed_wire_cuts_uplink_3x_on_table2_rows`
/// pins.
const PINNED_ROWS: [&str; 2] = ["mariadb-tpch4", "nginx-filedown"];

fn pool_cfg() -> PoolConfig {
    PoolConfig {
        nodes_per_array: 8,
        arrays: 1,
        ..Default::default()
    }
}

/// One replay of `row` under `wire`, seed 42, scale 2000, 4 nodes.
fn replay(row: &str, wire: WirePolicy) -> (ServeReport, Counters) {
    let pcfg = pool_cfg();
    let mut sim = PoolSim::with_pool(&pcfg, &EtherOnConfig::default());
    let spec = workload_named(row).expect("a Table 2 row");
    let ap = ArrivalParams { scale: 2_000, ..Default::default() };
    let arr = trace_arrivals(&spec, 42, &ap);
    let factories: Vec<_> = (0..4)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let params = ServeParams {
        batch_width: 4,
        prompt_len: ap.engine_prompt_len(),
        batch_window: SimTime::us(200),
        wire,
        ..Default::default()
    };
    let report = serve(&mut sim, factories, arr.requests, &params);
    let mut c = Counters::new();
    report.export_counters(&mut c);
    sim.export_counters(&mut c);
    (report, c)
}

fn wire_policy_ab(records: &mut Vec<BenchRecord>) {
    section("device-to-device streaming: host-uplink bytes per served token");
    let mut table = Table::new(vec![
        "row", "hairpin B/tok", "streamed B/tok", "reduction", "p2p bytes",
    ]);
    let mut reduction_ok = true;
    let mut identical = true;
    for row in ["mariadb-tpch4", "nginx-filedown", "rocksdb-write"] {
        let (hr, hc) = replay(row, WirePolicy::Hairpin);
        let (sr, sc) = replay(row, WirePolicy::Streamed);
        let (sr2, sc2) = replay(row, WirePolicy::Streamed);
        identical &= sc == sc2 && sr.host_bytes == sr2.host_bytes;
        assert_eq!(sr.tokens_out, hr.tokens_out, "{row}: wire policy changed content");
        let tokens = sr.tokens_out.max(1) as f64;
        let h = hc.get(names::FABRIC_BYTES_HOST_UPLINK) as f64 / tokens;
        let s = sc.get(names::FABRIC_BYTES_HOST_UPLINK) as f64 / tokens;
        let reduction = h / s.max(1e-9);
        if PINNED_ROWS.contains(&row) {
            reduction_ok &= reduction >= 3.0;
        }
        table.row(vec![
            row.to_string(),
            format!("{h:.1}"),
            format!("{s:.1}"),
            format!("{reduction:.2}x"),
            format!("{}", sc.get(names::FABRIC_BYTES_P2P)),
        ]);
        let name = format!("d2d_stream_{row}");
        records.push(BenchRecord::new(name.clone(), "host_bytes_per_token_hairpin", h));
        records.push(BenchRecord::new(name.clone(), "host_bytes_per_token_streamed", s));
        records.push(BenchRecord::new(name, "uplink_reduction", reduction));
    }
    println!("{}", table.render());
    assert!(reduction_ok, "a pinned row lost its >= 3x uplink reduction");
    assert!(identical, "same-seed streamed replays diverged");
    records.push(BenchRecord::new(
        "d2d_stream",
        "host_uplink_reduction_visible",
        if reduction_ok { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new(
        "d2d_stream",
        "same_seed_identical",
        if identical { 1.0 } else { 0.0 },
    ));
}

fn handoff_pipelining(records: &mut Vec<BenchRecord>) {
    section("prefill -> decode KV handoff: pipelined vs serial");
    let llm = all_llms().remove(0);
    let par = Parallelism { dp: 1, tp: 4, pp: 1 };
    let traffic = handoff_traffic(&llm, par, 64, 1, false);
    let mut f = Fabric::new(&pool_cfg(), &EtherOnConfig::default());
    let rs = stream_handoffs(&mut f, SimTime::ZERO, &traffic, SimTime::us(50));
    let r = &rs[0];
    println!(
        "{}: {} bytes in {} quanta — wire {}, effective {}, serial {} ({:.2}x)",
        llm.name,
        r.bytes,
        r.quanta,
        r.wire,
        r.effective,
        r.serial,
        r.speedup()
    );
    assert!(r.effective < r.serial, "pipelining must shrink the handoff critical path");
    records.push(BenchRecord::new("d2d_stream_handoff", "handoff_speedup", r.speedup()));
    records.push(BenchRecord::new(
        "d2d_stream_handoff",
        "quanta",
        r.quanta as f64,
    ));
}

fn main() {
    let mut records = Vec::new();
    wire_policy_ab(&mut records);
    handoff_pipelining(&mut records);
    emit_json("BENCH_d2d_stream.json", &records).expect("write BENCH_d2d_stream.json");
}
