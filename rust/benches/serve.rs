//! Trace-driven serving on PoolSim: replay Table 2 rows through
//! `coordinator::serve`, alone and while a replica boot storm runs on
//! the same clock.
//!
//! Emits machine-readable `BENCH_serve.json` ({name, metric, value})
//! records so perf is tracked across PRs.  Two record families:
//!
//! * invariant metrics the committed baselines gate now —
//!   `served_fraction` (conservation: every request answered),
//!   `same_seed_identical` (two same-seed replays byte-identical), and
//!   `storm_visible` (a boot storm inflates serve p99) are 1.0 by
//!   construction and regress to 0.x only when the property breaks;
//! * simulation-shape metrics (`makespan_ms`, `latency_p99_ns`,
//!   `queue_wait_ms`) — deterministic and machine-independent, reported
//!   as new benches until committed to `bench_baselines/`.

use dockerssd::benchkit::{bench, emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig};
use dockerssd::coordinator::{serve, EchoExecutor, ServeParams, ServeReport};
use dockerssd::layerstore::PoolLayerCache;
use dockerssd::metrics::{names, Counters, Table};
use dockerssd::pool::{DeploymentSpec, Orchestrator, PoolTopology, RestartPolicy};
use dockerssd::sim::PoolSim;
use dockerssd::util::SimTime;
use dockerssd::workloads::{trace_arrivals, workload_named, ArrivalParams};

const ROWS: [&str; 3] = ["mariadb-tpch4", "nginx-filedown", "rocksdb-write"];

fn pool_cfg() -> PoolConfig {
    PoolConfig {
        nodes_per_array: 8,
        arrays: 1,
        ..Default::default()
    }
}

/// One replay: `row`'s trace through `nodes` EchoExecutor nodes, with an
/// optional `storm`-replica boot storm sharing the clock.
fn replay(row: &str, seed: u64, scale: u64, nodes: usize, storm: u32) -> (ServeReport, Counters) {
    let pcfg = pool_cfg();
    let mut sim = PoolSim::with_pool(&pcfg, &EtherOnConfig::default());
    let spec = workload_named(row).expect("a Table 2 row");
    let ap = ArrivalParams { scale, ..Default::default() };
    let arr = trace_arrivals(&spec, seed, &ap);
    if storm > 0 {
        let topo = PoolTopology::build(&pcfg);
        let mut orch = Orchestrator::new();
        let mut cache = PoolLayerCache::new();
        let layers: Vec<(u64, u64)> = (0..4u64).map(|i| (0xB007 + i, 24 << 20)).collect();
        orch.boot_storm_sim(
            &mut sim,
            &topo,
            &DeploymentSpec {
                name: "storm".into(),
                image: "llm-worker".into(),
                replicas: storm,
                restart: RestartPolicy::OnFailure,
            },
            &mut cache,
            &layers,
        )
        .expect("storm placement");
    }
    let factories: Vec<_> = (0..nodes)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let params = ServeParams {
        batch_width: 4,
        // full write payloads stay in the prompt (no clipping)
        prompt_len: ap.engine_prompt_len(),
        batch_window: SimTime::us(200),
        ..Default::default()
    };
    let report = serve(&mut sim, factories, arr.requests, &params);
    let mut c = Counters::new();
    report.export_counters(&mut c);
    sim.export_counters(&mut c);
    (report, c)
}

fn fingerprint(report: &ServeReport, c: &Counters) -> (Vec<(&'static str, u64)>, Vec<(u64, u64)>) {
    (
        c.iter().collect(),
        report.responses.iter().map(|r| (r.id, r.latency.as_ns())).collect(),
    )
}

fn trace_replays(records: &mut Vec<BenchRecord>) {
    section("trace replay: Table 2 rows through the serve loop");
    let mut table = Table::new(vec![
        "row", "requests", "batches", "makespan", "p99", "host_uplink_bytes",
    ]);
    for row in ROWS {
        let (r1, c1) = replay(row, 42, 5_000, 4, 0);
        let (r2, c2) = replay(row, 42, 5_000, 4, 0);
        let identical = fingerprint(&r1, &c1) == fingerprint(&r2, &c2);
        assert!(identical, "{row}: same-seed replays diverged");
        let served = r1.responses.len() as f64 / r1.requests.max(1) as f64;
        assert!((served - 1.0).abs() < 1e-9, "{row}: dropped requests");
        table.row(vec![
            row.to_string(),
            format!("{}", r1.requests),
            format!("{}", r1.batches),
            format!("{}", r1.makespan),
            format!("{}", r1.latency.quantile(0.99)),
            format!("{}", c1.get(names::FABRIC_BYTES_HOST_UPLINK)),
        ]);
        let name = format!("trace_replay_{row}");
        records.push(BenchRecord::new(name.clone(), "served_fraction", served));
        records.push(BenchRecord::new(
            name.clone(),
            "same_seed_identical",
            if identical { 1.0 } else { 0.0 },
        ));
        records.push(BenchRecord::new(name.clone(), "makespan_ms", r1.makespan.as_ms_f64()));
        records.push(BenchRecord::new(
            name,
            "latency_p99_ns",
            r1.latency.quantile(0.99).as_ns() as f64,
        ));
    }
    println!("{}", table.render());
}

fn boot_storm_interference(records: &mut Vec<BenchRecord>) {
    section("serve-while-deploy: boot storm vs quiet pool");
    let row = "nginx-filedown";
    let (quiet, cq) = replay(row, 42, 2_000, 4, 0);
    let (stormy, cs) = replay(row, 42, 2_000, 4, 2);
    let p99_q = quiet.latency.quantile(0.99);
    let p99_s = stormy.latency.quantile(0.99);
    let inflation = p99_s.as_ns() as f64 / p99_q.as_ns().max(1) as f64;
    let wait_q = cq.get(names::FABRIC_QUEUE_WAIT_NS);
    let wait_s = cs.get(names::FABRIC_QUEUE_WAIT_NS);
    println!(
        "quiet p99 {p99_q}, under a 2-replica boot storm {p99_s} ({inflation:.2}x); \
         fabric queue wait {} -> {}",
        SimTime::ns(wait_q),
        SimTime::ns(wait_s)
    );
    assert!(p99_s > p99_q, "a boot storm must visibly inflate serve p99");
    assert!(wait_s > wait_q, "storm contention must be visible in queue wait");
    records.push(BenchRecord::new(
        "boot_storm_serve",
        "storm_visible",
        if p99_s > p99_q { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new("boot_storm_serve", "p99_inflation", inflation));
    records.push(BenchRecord::new(
        "boot_storm_serve",
        "queue_wait_ms",
        SimTime::ns(wait_s).as_ms_f64(),
    ));
}

fn main() {
    let mut records = Vec::new();
    trace_replays(&mut records);
    boot_storm_interference(&mut records);

    section("hot path: trace arrivals generation");
    let spec = workload_named("mariadb-tpch4").expect("row");
    let r = bench("trace_arrivals_tpch4_scale5000", || {
        let arr = trace_arrivals(&spec, 42, &ArrivalParams { scale: 5_000, ..Default::default() });
        std::hint::black_box(arr.requests.len());
    });
    records.push(BenchRecord::new(
        "trace_arrivals_tpch4_scale5000",
        "ns_per_op",
        r.mean.as_nanos() as f64,
    ));

    emit_json("BENCH_serve.json", &records).expect("write BENCH_serve.json");
}
