//! FTL write-path economics: fill vs churn on the per-node flash
//! ledger ([`dockerssd::pool::FtlBank`]).
//!
//! A sequential fill of the logical span programs every page exactly
//! once (WAF 1.0, no GC); sustained churn past the span forces garbage
//! collection, so relocated pages inflate WAF above 1.0 and block
//! erases raise `wear_max`.  The invariant metrics (`waf_floor`,
//! `wear_monotone`, `same_seed_identical`) are pinned at 1.0 in
//! `bench_baselines/BENCH_ftl_write.json`; the shape metrics
//! (`waf_milli`, `wear_max`, `gc_relocated_pages`, `ns_per_op`) are
//! recorded but not compared, tracking the model as it evolves.
//! Emits machine-readable `BENCH_ftl_write.json`.

use dockerssd::benchkit::{bench, emit_json, section, BenchRecord};
use dockerssd::metrics::{names, Counters, Table};
use dockerssd::pool::FtlBank;
use dockerssd::util::SimTime;

const PAGE: u64 = 64 << 10;

/// Sequential fill: one pass over the logical span, 1 MiB writes.
fn fill(records: &mut Vec<BenchRecord>) {
    section("fill: one sequential pass, no GC");
    let mut bank = FtlBank::default();
    let span_bytes = bank.logical_span() * PAGE;
    let mut t = SimTime::ZERO;
    let mut written = 0u64;
    while written < span_bytes {
        let r = bank.write(0, t, 1 << 20);
        t = r.done;
        written += 1 << 20;
    }
    let waf = bank.waf_milli_of(0);
    println!(
        "filled {written} bytes, WAF {:.3}x, wear_max {}",
        waf as f64 / 1000.0,
        bank.wear_max_of(0)
    );
    assert_eq!(waf, 1000, "a single sequential pass relocates nothing");
    records.push(BenchRecord::new("ftl_fill", "waf_milli", waf as f64));
}

/// Churn: 3x the logical span in 4 MiB writes — GC must run, WAF
/// rises above 1.0, wear accrues monotonically.
fn churn(records: &mut Vec<BenchRecord>) {
    section("churn: 3x span overwrite forces GC");
    let run = || {
        let mut bank = FtlBank::default();
        let span_bytes = bank.logical_span() * PAGE;
        let mut t = SimTime::ZERO;
        let mut written = 0u64;
        let mut wear_floor = 0u64;
        let mut monotone = true;
        while written < 3 * span_bytes {
            let r = bank.write(0, t, 4 << 20);
            t = r.done;
            written += 4 << 20;
            let w = bank.wear_max_of(0);
            monotone &= w >= wear_floor;
            wear_floor = w;
        }
        let mut c = Counters::new();
        bank.export_counters(&mut c);
        (c, monotone)
    };
    let (c, monotone) = run();
    let (c2, monotone2) = run();

    let mut table = Table::new(vec!["counter", "value"]);
    for key in [
        names::FTL_WAF,
        names::FTL_WEAR_MAX,
        names::FTL_GC_RELOCATED,
        names::FTL_HOST_PAGES,
        names::FTL_ERASES,
    ] {
        table.row(vec![key.to_string(), format!("{}", c.get(key))]);
    }
    println!("{}", table.render());

    let waf = c.get(names::FTL_WAF);
    assert!(waf > 1000, "3x-span churn must relocate live pages: WAF {waf}");
    assert!(c.get(names::FTL_GC_RELOCATED) > 0, "GC must have run");
    assert!(c.get(names::FTL_ERASES) > 0, "GC must erase victim blocks");
    assert!(monotone && monotone2, "wear_max must never decrease");
    assert_eq!(c, c2, "same traffic must price to the same ledger");

    // invariants: pinned at 1.0 in the committed baseline, so any
    // violation shows up as a benchdiff regression too
    records.push(BenchRecord::new(
        "ftl_churn",
        "waf_floor",
        if waf >= 1000 { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new(
        "ftl_churn",
        "wear_monotone",
        if monotone && monotone2 { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new(
        "ftl_churn",
        "same_seed_identical",
        if c == c2 { 1.0 } else { 0.0 },
    ));
    // shape: recorded, not compared — the flash model will move these
    records.push(BenchRecord::new("ftl_churn", "waf_milli", waf as f64));
    records.push(BenchRecord::new("ftl_churn", "wear_max", c.get(names::FTL_WEAR_MAX) as f64));
    records.push(BenchRecord::new(
        "ftl_churn",
        "gc_relocated_pages",
        c.get(names::FTL_GC_RELOCATED) as f64,
    ));
}

fn main() {
    let mut records = Vec::new();
    fill(&mut records);
    churn(&mut records);

    section("hot path: FtlBank::write");
    let mut bank = FtlBank::default();
    let mut t = SimTime::ZERO;
    let r = bench("ftl_bank_write_64k", || {
        let w = bank.write(0, t, 64 << 10);
        t = w.done;
    });
    records.push(BenchRecord::new("ftl_bank_write_64k", "ns_per_op", r.mean.as_nanos() as f64));

    emit_json("BENCH_ftl_write.json", &records).expect("write BENCH_ftl_write.json");
}
