//! Chaos under serve: replay the CI trace scenario while a seeded fault
//! schedule kills nodes, browns out links, and stalls the registry —
//! then measure what the self-healing loop preserved.
//!
//! Emits machine-readable `BENCH_chaos.json` ({name, metric, value})
//! records so resilience is tracked across PRs.  Two record families:
//!
//! * invariant metrics the committed baselines gate now —
//!   `same_seed_identical` (two same-seed chaos runs byte-identical),
//!   `healed_to_k` (every live chunk back to >=k holders post-run), and
//!   `served_fraction` (churn never loses a request) are 1.0 by
//!   construction and regress to 0.x only when the property breaks;
//! * simulation-shape metrics (`availability_fraction`,
//!   `latency_p99_under_churn_ns`, `heal_hidden_fraction`,
//!   `heal_bytes`) — deterministic and machine-independent, reported as
//!   new benches until committed to `bench_baselines/`.

use dockerssd::benchkit::{emit_json, section, BenchRecord};
use dockerssd::metrics::Table;
use dockerssd::smoke::{run, SmokeOutcome, SmokeParams, CHAOS_HEAL_K};

const SEEDS: [u64; 3] = [7, 42, 1984];

fn chaos_run(seed: u64) -> SmokeOutcome {
    run(&SmokeParams {
        chaos: Some(seed),
        ..SmokeParams::ci()
    })
    .expect("the CI smoke scenario runs")
}

fn main() {
    section("chaos replay: seeded fault schedules against the CI trace");
    let mut records = Vec::new();
    let mut table = Table::new(vec![
        "seed",
        "faults",
        "deaths",
        "availability",
        "p99_churn",
        "heal_bytes",
        "hidden",
    ]);
    for seed in SEEDS {
        let a = chaos_run(seed);
        let b = chaos_run(seed);
        let identical = a.counters == b.counters;
        assert!(identical, "seed {seed}: same-seed chaos runs diverged");
        let ch = a.chaos.as_ref().expect("chaos outcome present");
        let healed = ch.healed_to_k(CHAOS_HEAL_K);
        assert!(healed, "seed {seed}: pool not healed back to k holders");
        let served = a.report.responses.len() as f64 / a.arrivals.requests.max(1) as f64;
        assert!((served - 1.0).abs() < 1e-9, "seed {seed}: dropped requests");
        let p99 = a.report.latency.quantile(0.99);
        let hidden = ch.heal.bytes_hidden as f64 / ch.heal.bytes.max(1) as f64;
        table.row(vec![
            format!("{seed}"),
            format!("{}", ch.report.faults_injected),
            format!("{}", ch.report.node_deaths + ch.report.array_losses),
            format!("{:.4}", ch.report.availability_fraction()),
            format!("{p99}"),
            format!("{}", ch.heal.bytes),
            format!("{:.2}", hidden),
        ]);
        let name = format!("chaos_serve_seed{seed}");
        records.push(BenchRecord::new(
            name.clone(),
            "same_seed_identical",
            if identical { 1.0 } else { 0.0 },
        ));
        records.push(BenchRecord::new(
            name.clone(),
            "healed_to_k",
            if healed { 1.0 } else { 0.0 },
        ));
        records.push(BenchRecord::new(name.clone(), "served_fraction", served));
        records.push(BenchRecord::new(
            name.clone(),
            "availability_fraction",
            ch.report.availability_fraction(),
        ));
        records.push(BenchRecord::new(
            name.clone(),
            "latency_p99_under_churn_ns",
            p99.as_ns() as f64,
        ));
        records.push(BenchRecord::new(name.clone(), "heal_hidden_fraction", hidden));
        records.push(BenchRecord::new(name, "heal_bytes", ch.heal.bytes as f64));
    }
    println!("{}", table.render());

    emit_json("BENCH_chaos.json", &records).expect("write BENCH_chaos.json");
}
