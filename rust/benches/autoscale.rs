//! Flash-crowd autoscaling across every Table 2 row: replay each trace
//! against an under-provisioned serving pool twice — once with the
//! reactive controller (layers move at scale-out commit) and once with
//! predictive prefetch (layers move on the first hot tick) — and
//! compare cold-start p99 against each other and against the PR 4
//! boot-storm baseline (cold registry pulls over the WAN).
//!
//! Emits machine-readable `BENCH_autoscale.json` ({name, metric,
//! value}) records.  Two record families:
//!
//! * invariant metrics the committed baselines gate now —
//!   `no_request_lost` (autoscaling never drops a request, any row, any
//!   mode), `same_seed_identical` (two same-seed autoscaled replays are
//!   byte-identical), and `predictive_beats_reactive` (on the pinned
//!   flash-crowd rows, mariadb-tpch4 and nginx-filedown, predictive
//!   cold-start p99 is strictly below both the reactive p99 and the
//!   boot-storm baseline) are 1.0 by construction and regress to 0.0
//!   only when the property breaks;
//! * simulation-shape metrics (per-row `coldstart_p99_ns` for each
//!   mode, `warm_boots`, `scale_outs`, `prefetch_hidden_bytes`) —
//!   deterministic and machine-independent, reported as new benches
//!   until committed to `bench_baselines/`.

use dockerssd::benchkit::{emit_json, section, BenchRecord};
use dockerssd::metrics::Table;
use dockerssd::pool::{boot_storm_coldstart_baseline, flash_crowd};
use dockerssd::workloads::all_workloads;

const SEED: u64 = 42;
/// The rows the tier-1 test pins the strict predictive win on: heavy
/// flash crowds whose backlog far outlives the controller's sustain
/// window.
const PINNED: [&str; 2] = ["mariadb-tpch4", "nginx-filedown"];

fn main() {
    section("flash-crowd autoscaling: reactive vs predictive, every Table 2 row");
    let baseline = boot_storm_coldstart_baseline();
    println!("boot-storm cold-start baseline (2 cold WAN pulls): {baseline}\n");

    let mut records = Vec::new();
    let mut table = Table::new(vec![
        "workload",
        "outs_r",
        "outs_p",
        "warm_p",
        "p99_reactive",
        "p99_predictive",
        "hidden_bytes",
    ]);
    let mut lost = 0u64;
    let mut pinned_wins = 0usize;
    for w in all_workloads() {
        let row = w.full_name();
        let reactive = flash_crowd(&row, SEED, false).expect("table 2 row replays");
        let predictive = flash_crowd(&row, SEED, true).expect("table 2 row replays");
        for out in [&reactive, &predictive] {
            lost += (out.requests - out.report.responses.len()) as u64;
        }
        let (p99_r, p99_p) = (
            reactive.scale.report.coldstart_p99(),
            predictive.scale.report.coldstart_p99(),
        );
        if PINNED.contains(&row.as_str()) && p99_p < p99_r && p99_p < baseline {
            pinned_wins += 1;
        }
        table.row(vec![
            row.clone(),
            format!("{}", reactive.scale.report.scale_outs),
            format!("{}", predictive.scale.report.scale_outs),
            format!("{}", predictive.scale.report.warm_boots),
            format!("{p99_r}"),
            format!("{p99_p}"),
            format!("{}", predictive.scale.report.prefetch_hidden_bytes),
        ]);
        let name = format!("autoscale_{row}");
        records.push(BenchRecord::new(
            name.clone(),
            "coldstart_p99_reactive_ns",
            p99_r.as_ns() as f64,
        ));
        records.push(BenchRecord::new(
            name.clone(),
            "coldstart_p99_predictive_ns",
            p99_p.as_ns() as f64,
        ));
        records.push(BenchRecord::new(
            name.clone(),
            "scale_outs",
            predictive.scale.report.scale_outs as f64,
        ));
        records.push(BenchRecord::new(
            name.clone(),
            "warm_boots",
            predictive.scale.report.warm_boots as f64,
        ));
        records.push(BenchRecord::new(
            name,
            "prefetch_hidden_bytes",
            predictive.scale.report.prefetch_hidden_bytes as f64,
        ));
    }
    println!("{}", table.render());

    let a = flash_crowd("nginx-filedown", SEED, true).expect("replay");
    let b = flash_crowd("nginx-filedown", SEED, true).expect("replay");
    let identical = a.counters == b.counters;
    assert!(identical, "same-seed autoscaled replays diverged");
    assert_eq!(lost, 0, "autoscaling dropped {lost} requests");
    let beats = pinned_wins == PINNED.len();
    assert!(
        beats,
        "predictive won on {pinned_wins}/{} pinned rows",
        PINNED.len()
    );
    records.push(BenchRecord::new(
        "autoscale_invariants",
        "no_request_lost",
        if lost == 0 { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new(
        "autoscale_invariants",
        "same_seed_identical",
        if identical { 1.0 } else { 0.0 },
    ));
    records.push(BenchRecord::new(
        "autoscale_invariants",
        "predictive_beats_reactive",
        if beats { 1.0 } else { 0.0 },
    ));

    emit_json("BENCH_autoscale.json", &records).expect("write BENCH_autoscale.json");
}
