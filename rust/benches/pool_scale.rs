//! Pool-scale trace replay: the calendar event queue, interned fabric
//! hot paths, and O(active) WFQ bookkeeping under a 1k-node pool
//! replaying a full Table 2 trace (≥1M requests end-to-end).
//!
//! Emits machine-readable `BENCH_pool_scale.json` ({name, metric,
//! value}) records.  Two record families:
//!
//! * invariant metrics the committed baselines gate now —
//!   `served_fraction` (every request answered at both pool sizes) and
//!   `same_seed_identical` (two same-seed 64-node replays
//!   byte-identical) are 1.0 by construction;
//! * throughput metrics (`events_per_sec`, `wall_ms`,
//!   `events_per_sec_1k_over_64`) — wall-clock figures, reported as new
//!   benches until a CI-runner baseline is committed.  The scale ratio
//!   is additionally asserted in-process: a 1024-node pool must retire
//!   events at no worse than 3x below the 64-node rate, i.e. per-event
//!   cost stays roughly flat across a 16x pool-size jump.

use std::time::Instant;

use dockerssd::benchkit::{emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig};
use dockerssd::coordinator::{serve, EchoExecutor, ServeParams, ServeReport};
use dockerssd::metrics::{Counters, Table};
use dockerssd::sim::PoolSim;
use dockerssd::util::SimTime;
use dockerssd::workloads::{trace_arrivals, workload_named, ArrivalParams};

/// Table 2 row with io_count = 1_100_000: scale 1 replays the full
/// trace (~1.1M requests), scale 11 cuts the same mix to ~100k.
const ROW: &str = "mariadb-tpch4";

struct Replay {
    report: ServeReport,
    counters: Counters,
    events: u64,
    wall_s: f64,
}

/// One end-to-end replay of `ROW` on an `arrays * 32`-node pool.  The
/// wall clock wraps only the simulation (arrival generation excluded),
/// so `events / wall_s` is the substrate's event rate.
fn replay(arrays: u32, scale: u64, seed: u64) -> Replay {
    let pcfg = PoolConfig {
        nodes_per_array: 32,
        arrays,
        ..Default::default()
    };
    let spec = workload_named(ROW).expect("a Table 2 row");
    let ap = ArrivalParams { scale, ..Default::default() };
    let arr = trace_arrivals(&spec, seed, &ap);
    let mut sim = PoolSim::with_pool(&pcfg, &EtherOnConfig::default());
    let nodes = sim.nodes();
    let factories: Vec<_> = (0..nodes)
        .map(|_| || Ok::<_, anyhow::Error>(EchoExecutor))
        .collect();
    let params = ServeParams {
        batch_width: 8,
        prompt_len: ap.engine_prompt_len(),
        batch_window: SimTime::us(200),
        ..Default::default()
    };
    let start = Instant::now();
    let report = serve(&mut sim, factories, arr.requests, &params);
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);
    let events = sim.queue.processed();
    let mut counters = Counters::new();
    report.export_counters(&mut counters);
    sim.export_counters(&mut counters);
    Replay { report, counters, events, wall_s }
}

fn fingerprint(r: &Replay) -> (Vec<(&'static str, u64)>, Vec<(u64, u64)>) {
    (
        r.counters.iter().collect(),
        r.report.responses.iter().map(|x| (x.id, x.latency.as_ns())).collect(),
    )
}

fn main() {
    let mut records = Vec::new();

    section("pool scale: 64 vs 1024 nodes, same trace mix");
    let mut table = Table::new(vec![
        "nodes", "requests", "events", "wall", "events/sec",
    ]);
    // (record name, arrays, trace scale): 32x2 = 64 nodes at ~100k
    // requests, 32x32 = 1024 nodes replaying the full ~1.1M-request row
    let runs = [("pool_scale_64n", 2u32, 11u64), ("pool_scale_1024n", 32, 1)];
    let mut rates = [0.0f64; 2];
    for (i, (name, arrays, scale)) in runs.iter().enumerate() {
        let r = replay(*arrays, *scale, 42);
        let served = r.report.responses.len() as f64 / r.report.requests.max(1) as f64;
        assert!((served - 1.0).abs() < 1e-9, "{name}: dropped requests");
        let rate = r.events as f64 / r.wall_s;
        rates[i] = rate;
        table.row(vec![
            format!("{}", 32 * arrays),
            format!("{}", r.report.requests),
            format!("{}", r.events),
            format!("{:.2}s", r.wall_s),
            format!("{:.0}", rate),
        ]);
        records.push(BenchRecord::new(*name, "served_fraction", served));
        records.push(BenchRecord::new(*name, "requests", r.report.requests as f64));
        records.push(BenchRecord::new(*name, "events_per_sec", rate));
        records.push(BenchRecord::new(*name, "wall_ms", r.wall_s * 1e3));
    }
    println!("{}", table.render());

    let ratio = rates[1] / rates[0].max(1e-9);
    println!("1024-node event rate is {ratio:.2}x the 64-node rate");
    assert!(
        ratio >= 1.0 / 3.0,
        "per-event cost blew up with pool size: 1024-node rate is {ratio:.2}x the 64-node rate"
    );
    records.push(BenchRecord::new("pool_scale", "events_per_sec_1k_over_64", ratio));

    section("determinism: same seed, byte-identical counters");
    let a = replay(2, 110, 7);
    let b = replay(2, 110, 7);
    let identical = fingerprint(&a) == fingerprint(&b);
    assert!(identical, "same-seed replays diverged");
    println!(
        "two seed-7 replays: {} counters, {} responses, identical",
        a.counters.iter().count(),
        a.report.responses.len()
    );
    records.push(BenchRecord::new(
        "pool_scale",
        "same_seed_identical",
        if identical { 1.0 } else { 0.0 },
    ));

    emit_json("BENCH_pool_scale.json", &records).expect("write BENCH_pool_scale.json");
}
