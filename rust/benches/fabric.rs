//! Fabric contention scenarios: replica-boot storms over shared vs
//! disjoint links, prefetch overlap, and a multi-tenant traffic mix
//! (LLM collective steps + layer fetches on the same wires).
//!
//! Emits machine-readable `BENCH_fabric.json` ({name, metric, value}
//! records) so perf is tracked across PRs.

use dockerssd::benchkit::{bench, emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig};
use dockerssd::fabric::{Endpoint, Fabric, Priority};
use dockerssd::layerstore::PoolLayerCache;
use dockerssd::llm::disagg::{pool_step_time, step_traffic};
use dockerssd::llm::{all_llms, Parallelism};
use dockerssd::metrics::Table;
use dockerssd::pool::{FtlBank, PoolTopology, WireCtx};
use dockerssd::util::SimTime;

fn pool_cfg(nodes_per_array: u32, arrays: u32) -> PoolConfig {
    PoolConfig {
        nodes_per_array,
        arrays,
        ..Default::default()
    }
}

fn fabric(nodes_per_array: u32, arrays: u32) -> Fabric {
    Fabric::new(&pool_cfg(nodes_per_array, arrays), &EtherOnConfig::default())
}

/// Boot storm: N replicas pull one image at the same instant, either
/// all over one array backplane or spread over N disjoint arrays.
fn boot_storm(records: &mut Vec<BenchRecord>) {
    section("boot storm: shared vs disjoint links");
    let image_bytes = 16 << 20;
    let mut table = Table::new(vec!["replicas", "single", "shared", "disjoint", "shared/single"]);
    for n in [2u32, 4, 8] {
        let mut shared_fabric = fabric(n + 1, 1);
        let single = shared_fabric.estimate(Endpoint::Node(0), Endpoint::Node(1), image_bytes);
        let mut shared = SimTime::ZERO;
        for i in 1..=n {
            let r = shared_fabric.transfer(
                SimTime::ZERO,
                Endpoint::Node(0),
                Endpoint::Node(i),
                image_bytes,
                Priority::Foreground,
            );
            shared = shared.max(r.finish);
        }
        let mut disjoint_fabric = fabric(2, n);
        let mut disjoint = SimTime::ZERO;
        for a in 0..n {
            let r = disjoint_fabric.transfer(
                SimTime::ZERO,
                Endpoint::Node(2 * a),
                Endpoint::Node(2 * a + 1),
                image_bytes,
                Priority::Foreground,
            );
            disjoint = disjoint.max(r.finish);
        }
        let ratio = shared.as_ns() as f64 / single.as_ns() as f64;
        table.row(vec![
            format!("{n}"),
            format!("{single}"),
            format!("{shared}"),
            format!("{disjoint}"),
            format!("{ratio:.2}x"),
        ]);
        records.push(BenchRecord::new(
            format!("boot_storm_shared_n{n}"),
            "makespan_ms",
            shared.as_ms_f64(),
        ));
        records.push(BenchRecord::new(
            format!("boot_storm_disjoint_n{n}"),
            "makespan_ms",
            disjoint.as_ms_f64(),
        ));
        records.push(BenchRecord::new(
            format!("boot_storm_n{n}"),
            "shared_over_single",
            ratio,
        ));
        assert!(ratio > (n as f64) * 0.85, "shared link must serialize: {ratio:.2}");
    }
    println!("{}", table.render());
}

/// Prefetch overlap: a background image prefetch is mid-flight; how
/// much does it delay a foreground fetch on the same link?
fn prefetch_overlap(records: &mut Vec<BenchRecord>) {
    section("prefetch overlap: background yields within one frame quantum");
    let mut f = fabric(8, 1);
    let idle = f.estimate(Endpoint::Node(2), Endpoint::Node(3), 1 << 20);
    f.transfer(
        SimTime::ZERO,
        Endpoint::Node(0),
        Endpoint::Node(1),
        256 << 20,
        Priority::Background,
    );
    let fg = f.transfer(
        SimTime::ZERO,
        Endpoint::Node(2),
        Endpoint::Node(3),
        1 << 20,
        Priority::Foreground,
    );
    println!(
        "idle fetch {idle}, with 256MiB prefetch in flight {} (queue wait {})",
        fg.latency(),
        fg.queue_wait()
    );
    records.push(BenchRecord::new(
        "prefetch_overlap",
        "fg_queue_wait_ns",
        fg.queue_wait().as_ns() as f64,
    ));
    records.push(BenchRecord::new(
        "prefetch_overlap",
        "prefetch_bytes_hidden",
        f.stats.prefetch_bytes_hidden as f64,
    ));
}

/// Multi-tenant mix: a tensor-parallel decode step and a replica's
/// layer fetches share one array; compare each against running alone.
fn tenant_mix(records: &mut Vec<BenchRecord>) {
    section("multi-tenant mix: LLM collective + layer fetches");
    let llm = all_llms().remove(0);
    let par = Parallelism { dp: 1, tp: 8, pp: 1 };
    let traffic = step_traffic(&llm, par, 32_768, 1, true, false);

    let mut alone = fabric(16, 1);
    let step_alone = pool_step_time(&mut alone, SimTime::ZERO, &traffic);

    let cfg = pool_cfg(16, 1);
    let topo = PoolTopology::build(&cfg);
    let mut mixed = fabric(16, 1);
    let mut cache = PoolLayerCache::new();
    cache.register(8, 0xF00D);
    let layer_bytes = 8 << 20;
    let mut bank = FtlBank::default();
    let (_, fetch_lat) = cache.fetch(
        &mut WireCtx::at(&mut mixed, &topo, &mut bank, SimTime::ZERO),
        9,
        0xF00D,
        layer_bytes,
    );
    let step_mixed = pool_step_time(&mut mixed, SimTime::ZERO, &traffic);

    println!(
        "collective step alone {step_alone}, behind a {}B layer fetch {step_mixed} (fetch {fetch_lat})",
        layer_bytes
    );
    records.push(BenchRecord::new("tenant_mix", "step_alone_ms", step_alone.as_ms_f64()));
    records.push(BenchRecord::new("tenant_mix", "step_mixed_ms", step_mixed.as_ms_f64()));
    records.push(BenchRecord::new(
        "tenant_mix",
        "congestion_factor",
        step_mixed.as_ns() as f64 / step_alone.as_ns().max(1) as f64,
    ));
    assert!(step_mixed >= step_alone, "sharing a wire cannot be free");
}

/// Event-driven engine: a preempted background prefetch is re-timed —
/// its real finish (after yielding to a foreground burst) vs the
/// optimistic busy-until figure the sync path would have kept.
fn retimed_prefetch(records: &mut Vec<BenchRecord>) {
    section("event engine: preempted prefetch re-timed, not optimistic");
    let mut f = fabric(8, 1);
    let bytes = 64u64 << 20;
    let optimistic = f.estimate(Endpoint::Node(0), Endpoint::Node(1), bytes);
    let bg = f.schedule(
        SimTime::ZERO,
        Endpoint::Node(0),
        Endpoint::Node(1),
        bytes,
        Priority::Background,
    );
    for i in 1..=4u64 {
        f.schedule(
            SimTime::ms(i),
            Endpoint::Node(2),
            Endpoint::Node(3),
            4 << 20,
            Priority::Foreground,
        );
    }
    f.run_to_idle();
    let r = f.receipt_of(bg).expect("engine drained");
    let ratio = r.finish.as_ns() as f64 / optimistic.as_ns().max(1) as f64;
    println!("optimistic {optimistic}, re-timed {} ({ratio:.2}x)", r.finish);
    records.push(BenchRecord::new("retimed_prefetch", "retimed_over_optimistic", ratio));
    records.push(BenchRecord::new(
        "retimed_prefetch",
        "retimed_transfers",
        f.stats.retimed_transfers as f64,
    ));
    assert!(r.finish > optimistic, "preempted prefetch must be re-timed");
}

fn main() {
    let mut records = Vec::new();
    boot_storm(&mut records);
    prefetch_overlap(&mut records);
    tenant_mix(&mut records);
    retimed_prefetch(&mut records);

    section("hot path: Fabric::transfer");
    let mut f = fabric(16, 4);
    let mut i = 0u32;
    let r = bench("fabric_transfer_cross_array", || {
        let from = Endpoint::Node(i % 32);
        let to = Endpoint::Node((i + 17) % 32);
        f.transfer(SimTime::ns(i as u64), from, to, 4096, Priority::Foreground);
        i = i.wrapping_add(1);
    });
    records.push(BenchRecord::new(
        "fabric_transfer_cross_array",
        "ns_per_op",
        r.mean.as_nanos() as f64,
    ));

    emit_json("BENCH_fabric.json", &records).expect("write BENCH_fabric.json");
}
