//! Bench E4 — Figure 11: all six data-processing models across the 13
//! Table 2 workloads, normalized to D-VirtFW, plus the paper's aggregate
//! claims and an end-to-end substrate replay measurement.

use dockerssd::benchkit::{bench, section};
use dockerssd::config::SystemConfig;
use dockerssd::firmware::CostModel;
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::models::{fig11_row, geomean_ratio, ModelKind};
use dockerssd::ssd::SsdDevice;
use dockerssd::util::SimTime;
use dockerssd::workloads::all_workloads;

fn main() {
    let c = CostModel::calibrated();

    section("Figure 11: normalized latency (D-VirtFW = 1.0)");
    println!(
        "{:<16} {:>7} {:>8} {:>8} {:>8} {:>9} {:>9}",
        "workload", "Host", "P.ISP-R", "P.ISP-V", "D-Naive", "D-FullOS", "D-VirtFW"
    );
    for w in all_workloads() {
        let row = fig11_row(&w, &c);
        print!("{:<16}", w.full_name());
        for (_, _, norm) in &row {
            print!(" {:>8.2}", norm);
        }
        println!();
    }

    section("aggregate geomean ratios vs D-VirtFW");
    for (m, paper) in [
        (ModelKind::Host, "1.3x"),
        (ModelKind::PIspR, "1.6x"),
        (ModelKind::PIspV, "1.6x"),
        (ModelKind::DNaive, "1.8x"),
        (ModelKind::DFullOs, "1.6x"),
    ] {
        println!(
            "  {:<9} {:.2}x  (paper ~{})",
            m.name(),
            geomean_ratio(m, ModelKind::DVirtFw, &c),
            paper
        );
    }
    println!(
        "  P.ISP-V/P.ISP-R {:.3} (paper 0.863) | D-FullOS/P.ISP-V {:.3} (paper 1.093) | D-Naive/D-FullOS {:.3} (paper 1.128)",
        geomean_ratio(ModelKind::PIspV, ModelKind::PIspR, &c),
        geomean_ratio(ModelKind::DFullOs, ModelKind::PIspV, &c),
        geomean_ratio(ModelKind::DNaive, ModelKind::DFullOs, &c),
    );

    section("hot paths");
    let ws = all_workloads();
    bench("fig11: 13 workloads x 6 models", || {
        for w in &ws {
            std::hint::black_box(fig11_row(w, &c));
        }
    });

    // substrate-level: λFS file I/O through the flash timing model
    let cfg = SystemConfig::default();
    let mut dev = SsdDevice::new(cfg.ssd.clone());
    let mut fs = LambdaFs::over_device(&dev);
    let body = vec![0xA5u8; 64 * 1024];
    fs.write_file(&mut dev, SimTime::ZERO, "/data/bench", &body, LockSide::Isp)
        .unwrap();
    bench("lambda-fs 64KB read via ICL+FTL+flash", || {
        std::hint::black_box(
            fs.read_file(&mut dev, SimTime::ZERO, "/data/bench", LockSide::Isp).unwrap(),
        );
    });
    bench("lambda-fs 64KB write via ICL+FTL+flash", || {
        std::hint::black_box(
            fs.write_file(&mut dev, SimTime::ZERO, "/data/bench", &body, LockSide::Isp).unwrap(),
        );
    });
}
