//! Bench E7/E8 — Figure 13: sequence-length sensitivity (a/b, with the
//! 256 / 1024 crossovers and ~9.5x convergence) and batch-size
//! sensitivity (c/d, modest <=1.4x gains).

use dockerssd::benchkit::{bench, section};
use dockerssd::llm::all_llms;
use dockerssd::llm::disagg::{batch_sweep, crossover_seq, seq_sweep};

fn main() {
    let llms = all_llms();
    let lamda = &llms[0];
    let megatron = &llms[7];

    section("Figure 13a/b: sequence-length sweep (D-Cache speedup over H-Cache)");
    let seqs: Vec<u64> = (6..=17).map(|p| 1u64 << p).collect();
    for (llm, nodes, paper) in [(lamda, 16u32, 256u64), (megatron, 128u32, 1024u64)] {
        println!("\n{} on {} nodes:", llm.name, nodes);
        for (s, sp) in seq_sweep(llm, nodes, &seqs, 1) {
            let marker = if sp >= 1.0 { "D wins" } else { "H wins" };
            println!("  seq {:>7}: {:>6.2}x  {}", s, sp, marker);
        }
        println!(
            "  crossover {:?} (paper {}); long-sequence convergence ~9.5x",
            crossover_seq(llm, nodes),
            paper
        );
    }

    section("Figure 13c/d: batch-size sweep at seq 512");
    let batches = [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for (llm, nodes) in [(lamda, 16u32), (megatron, 128u32)] {
        println!("\n{} on {} nodes:", llm.name, nodes);
        for (b, sp) in batch_sweep(llm, nodes, 512, &batches) {
            println!("  batch {:>4}: {:>5.2}x", b, sp);
        }
    }
    println!("\npaper: modest improvement, max ~1.3x");

    section("hot paths");
    bench("seq sweep 12 points (lamda, 16 nodes)", || {
        std::hint::black_box(seq_sweep(lamda, 16, &seqs, 1));
    });
    bench("batch sweep 10 points (megatron, 128 nodes)", || {
        std::hint::black_box(batch_sweep(megatron, 128, 512, &batches));
    });
}
