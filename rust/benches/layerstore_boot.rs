//! Replica-boot cost: registry-only pulls vs the content-addressed
//! layerstore (dedup + CoW + pool-wide peer fetch).
//!
//! The claim under test (ISSUE 1 acceptance): booting N >= 4 replicas of
//! one image across the pool moves at least 2x fewer bytes over the
//! registry WAN than the registry-only path — replica-boot cost scales
//! with *unique* bytes, not replica count.  (In fact only the first cold
//! node ever crosses the WAN, so the reduction is ~N-fold.)
//!
//! All transfer time comes from the shared [`Fabric`]: registry pulls
//! queue on the WAN + host uplink, peer fetches queue on the array
//! backplane, and placement-time prefetch rides the background lane.
//! Emits machine-readable `BENCH_layerstore_boot.json`.

use dockerssd::benchkit::{emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig, SsdConfig};
use dockerssd::docker::{MiniDocker, Registry};
use dockerssd::fabric::{Fabric, LinkClass};
use dockerssd::firmware::VirtualFw;
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::layerstore::{LayerStore, PoolLayerCache};
use dockerssd::metrics::{names, Counters, Table};
use dockerssd::pool::{
    DeploymentSpec, FtlBank, Orchestrator, PoolTopology, RestartPolicy, WireCtx,
};
use dockerssd::ssd::SsdDevice;
use dockerssd::util::{human_bytes, SimTime};

/// One DockerSSD's full stack.
struct Node {
    dev: SsdDevice,
    fs: LambdaFs,
    fw: VirtualFw,
    md: MiniDocker,
    store: LayerStore,
}

impl Node {
    fn new(cfg: &SsdConfig) -> Node {
        let dev = SsdDevice::new(cfg.clone());
        let fs = LambdaFs::over_device(&dev);
        Node {
            fw: VirtualFw::new(cfg),
            md: MiniDocker::new(),
            store: LayerStore::default(),
            dev,
            fs,
        }
    }
}

fn pool(n: u32) -> (PoolTopology, Fabric, Vec<Node>) {
    let pcfg = PoolConfig {
        nodes_per_array: n,
        arrays: 1,
        ..Default::default()
    };
    let scfg = SsdConfig::default();
    let nodes = (0..n).map(|_| Node::new(&scfg)).collect();
    let fabric = Fabric::new(&pcfg, &EtherOnConfig::default());
    (PoolTopology::build(&pcfg), fabric, nodes)
}

fn registry() -> (Registry, u64) {
    let mut reg = Registry::new();
    reg.publish(
        "svc",
        "latest",
        "svc --serve /data",
        &[256 << 10, 128 << 10, 64 << 10],
        42,
    );
    let (_, blobs) = reg.fetch("svc").unwrap();
    let image_bytes = blobs.iter().map(|b| b.bytes.len() as u64).sum();
    (reg, image_bytes)
}

/// Seed path: every replica pulls the whole image from the registry
/// into its node's private namespace, then materializes the overlay.
/// Since ISSUE 3 `MiniDocker::pull` itself routes the registry bytes
/// over the shared fabric, so the WAN/uplink contention between
/// concurrent pulls needs no manual layering here — the fabric's own
/// `RegistryWan` byte counter is the ground truth.
fn boot_registry_only(
    replicas: u32,
    nnodes: u32,
    reg: &Registry,
    _image_bytes: u64,
) -> (u64, SimTime) {
    let (topo, mut fabric, mut nodes) = pool(nnodes);
    let mut bank = FtlBank::default();
    let mut total = SimTime::ZERO;
    for r in 0..replicas {
        let nid = r % nnodes;
        let node = &mut nodes[nid as usize];
        let pulled = node
            .md
            .pull(
                &mut node.fw,
                &mut node.fs,
                &mut node.dev,
                reg,
                &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
                nid,
                "svc",
            )
            .expect("pull");
        let ran = node
            .md
            .run(&mut node.fw, &mut node.fs, &mut node.dev, pulled.done, "svc")
            .expect("run");
        total += ran.done;
    }
    let wan_bytes = fabric.link(LinkClass::RegistryWan).map_or(0, |q| q.bytes);
    (wan_bytes, total.scale(1.0 / replicas as f64))
}

/// LayerStore path: locality-aware placement (which kicks off background
/// prefetch over the fabric), peer fetch for layers the pool already
/// holds, dedup'd install, CoW writable layer per replica.
fn boot_via_layerstore(
    replicas: u32,
    nnodes: u32,
    reg: &Registry,
    cache: &mut PoolLayerCache,
    counters: &mut Counters,
) -> (u64, SimTime) {
    let (topo, mut fabric, mut nodes) = pool(nnodes);
    let mut orch = Orchestrator::new();
    let (manifest, blobs) = reg.fetch("svc").unwrap();
    let layers: Vec<(u64, u64)> = blobs
        .iter()
        .map(|b| (b.digest, b.bytes.len() as u64))
        .collect();
    let spec = DeploymentSpec {
        name: "svc".into(),
        image: "svc".into(),
        replicas,
        restart: RestartPolicy::OnFailure,
    };
    let mut bank = FtlBank::default();
    let placed = orch
        .deploy_with_layers(
            &mut WireCtx::at(&mut fabric, &topo, &mut bank, SimTime::ZERO),
            &spec,
            cache,
            &layers,
        )
        .expect("placement");

    let mut total = SimTime::ZERO;
    for nid in placed {
        let node = &mut nodes[nid as usize];
        let mut t = SimTime::ZERO;
        for blob in blobs {
            // placement already prefetched the layer over the fabric's
            // background lane; boot-time fetch is a (free) local hit
            let (_src, xfer) = cache.fetch(
                &mut WireCtx::at(&mut fabric, &topo, &mut bank, t),
                nid,
                blob.digest,
                blob.bytes.len() as u64,
            );
            t += xfer;
            // install through the firmware handler: dedups into the store
            let r = node
                .fw
                .install
                .install_blob(&mut node.fs, &mut node.dev, &mut node.store, t, &blob.bytes)
                .expect("install");
            t = r.done;
        }
        let m = node
            .fs
            .write_file(
                &mut node.dev,
                t,
                &format!("/images/manifest/{}", manifest.name),
                manifest.to_json().dump().as_bytes(),
                LockSide::Isp,
            )
            .expect("manifest");
        t = m.done;
        let ran = node
            .md
            .run_cow(&mut node.fw, &mut node.fs, &mut node.dev, &mut node.store, t, "svc")
            .expect("run_cow");
        // each replica dirties a page of config: a CoW break, not a copy
        let layer = node.md.cow_layer_of(&ran.output).expect("cow layer");
        node.md
            .cow
            .write_at(
                &mut node.store,
                &mut node.fs,
                &mut node.dev,
                ran.done,
                layer,
                0,
                &[0xC0; 4096],
            )
            .expect("dirty config");
        total += ran.done;
    }
    for node in &nodes {
        node.store.export_counters(counters);
        node.md.cow.export_counters(counters);
    }
    cache.export_counters(counters);
    fabric.export_counters(counters);
    bank.export_counters(counters);
    (cache.bytes_from_registry, total.scale(1.0 / replicas as f64))
}

fn main() {
    section("replica boot: registry-only vs layerstore");
    let (reg, image_bytes) = registry();
    println!(
        "image: svc:latest, 3 layers, {} (pool of 8 DockerSSDs, fabric-routed transfers)\n",
        human_bytes(image_bytes)
    );

    let mut table = Table::new(vec![
        "replicas",
        "wan_bytes (registry-only)",
        "wan_bytes (layerstore)",
        "reduction",
        "peer_fetches",
        "mean_boot (registry-only)",
        "mean_boot (layerstore)",
    ]);
    let mut records = Vec::new();

    for replicas in [1u32, 2, 4, 8, 16] {
        let (base_bytes, base_boot) = boot_registry_only(replicas, 8, &reg, image_bytes);
        let mut cache = PoolLayerCache::new();
        let mut counters = Counters::new();
        let (store_bytes, store_boot) =
            boot_via_layerstore(replicas, 8, &reg, &mut cache, &mut counters);
        let reduction = base_bytes as f64 / store_bytes.max(1) as f64;
        table.row(vec![
            format!("{replicas}"),
            human_bytes(base_bytes),
            human_bytes(store_bytes),
            format!("{reduction:.1}x"),
            format!("{}", cache.peer_fetches),
            format!("{base_boot}"),
            format!("{store_boot}"),
        ]);
        records.push(BenchRecord::new(
            format!("replica_boot_n{replicas}"),
            "wan_reduction",
            reduction,
        ));
        records.push(BenchRecord::new(
            format!("replica_boot_n{replicas}"),
            "mean_boot_ms_layerstore",
            store_boot.as_ms_f64(),
        ));
        records.push(BenchRecord::new(
            format!("replica_boot_n{replicas}"),
            "mean_boot_ms_registry_only",
            base_boot.as_ms_f64(),
        ));
        if replicas >= 4 {
            assert!(
                reduction >= 2.0,
                "acceptance: >=2x WAN-byte reduction at {replicas} replicas, got {reduction:.2}x"
            );
        }
        if replicas == 16 {
            println!("{}", table.render());
            println!("layerstore + fabric counters (16-replica run, summed over nodes):");
            let mut ct = Table::new(vec!["counter", "value"]);
            for key in [
                names::DEDUP_HITS,
                names::BYTES_WRITTEN,
                names::BYTES_DEDUPED,
                names::COW_BREAKS,
                names::PEER_FETCHES,
                names::REGISTRY_FETCHES,
                names::BYTES_NOT_TRANSFERRED,
                names::FABRIC_BYTES_ARRAY,
                names::FABRIC_BYTES_WAN,
                names::FABRIC_QUEUE_WAIT_NS,
                names::FABRIC_PREFETCH_BYTES,
                names::FABRIC_PREFETCH_HIDDEN,
                names::FTL_HOST_PAGES,
                names::FTL_WAF,
            ] {
                ct.row(vec![key.to_string(), format!("{}", counters.get(key))]);
            }
            println!("{}", ct.render());
        }
    }
    println!("boot cost scales with unique bytes, not replica count: OK");
    emit_json("BENCH_layerstore_boot.json", &records).expect("write BENCH_layerstore_boot.json");
}
