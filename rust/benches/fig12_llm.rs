//! Bench E5/E6 — Figure 12: optimal parallelism per disaggregation
//! scenario (12a) and the compute/memory breakdown with the paper's
//! aggregate ratios (12b), plus the parallelism-search hot path.

use dockerssd::benchkit::{bench, section};
use dockerssd::llm::all_llms;
use dockerssd::llm::disagg::{aggregate_ratio, fig12_sweep, nodes_for, DisaggModel};
use dockerssd::llm::parallelism::find_optimal;

fn main() {
    let seq = 32_768;

    section("Figure 12a: optimal parallelism (32K seq, batch 1)");
    let rs = fig12_sweep(seq, 1);
    println!(
        "{:<14} {:>5}  {:>22} {:>22} {:>22} {:>22}",
        "model", "nodes", "H-NoCache", "H-Cache", "D-NoCache", "D-Cache"
    );
    for (i, llm) in all_llms().iter().enumerate() {
        print!("{:<14} {:>5} ", llm.name, nodes_for(i));
        for d in DisaggModel::ALL {
            let cell = rs
                .iter()
                .find(|r| r.model == llm.name && r.disagg == d)
                .map(|r| format!("{}({})", r.choice.par.dominant().name(), r.choice.par.label()))
                .unwrap_or_else(|| "infeasible".into());
            print!(" {:>22}", cell);
        }
        println!();
    }
    println!("paper: NoCache -> pipeline; Cache -> tensor");

    section("Figure 12b: Compute/Memory breakdown (seconds)");
    println!(
        "{:<14} {:>11} {:>12} {:>12} {:>10} {:>12}",
        "model", "scenario", "compute", "memory", "comm", "total"
    );
    for r in &rs {
        println!(
            "{:<14} {:>11} {:>12.1} {:>12.1} {:>10.2} {:>12.1}",
            r.model,
            r.disagg.name(),
            r.time().compute,
            r.time().memory,
            r.time().comm,
            r.time().total()
        );
    }

    section("aggregate ratios (paper targets)");
    println!(
        "  H-Cache over H-NoCache: {:.0}x (paper 421x)",
        aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::HostCache, seq, 1)
    );
    println!(
        "  D-Cache over D-NoCache: {:.0}x (paper 4.6Kx)",
        aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::DockerCache, seq, 1)
    );
    println!(
        "  D-Cache over H-Cache:   {:.1}x (paper 7.9x)",
        aggregate_ratio(DisaggModel::HostCache, DisaggModel::DockerCache, seq, 1)
    );
    println!(
        "  D-NoCache vs H-NoCache: {:.1}x slower (paper 1.7x)",
        aggregate_ratio(DisaggModel::DockerNoCache, DisaggModel::HostNoCache, seq, 1)
    );
    println!(
        "  D-Cache over H-NoCache: {:.0}x (paper 3.2Kx)",
        aggregate_ratio(DisaggModel::HostNoCache, DisaggModel::DockerCache, seq, 1)
    );

    section("hot paths");
    let gpt3 = all_llms().into_iter().find(|m| m.name == "gpt3-175B").unwrap();
    let dev = DisaggModel::DockerCache.device();
    bench("parallelism search, 128 nodes", || {
        std::hint::black_box(find_optimal(&gpt3, &dev, 128, seq, 1, true));
    });
    bench("full fig12 sweep (8 models x 4 scenarios)", || {
        std::hint::black_box(fig12_sweep(seq, 1));
    });
}
