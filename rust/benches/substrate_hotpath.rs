//! Substrate hot-path microbenchmarks (§Perf, L3): the pieces that sit
//! on the simulated request path — NVMe queue service, Ether-oN frame
//! round-trip, flash timing model, FTL mapping, λFS path walk, TCP
//! segment processing, JSON manifest parse, batcher/router.

use std::net::Ipv4Addr;

use dockerssd::benchkit::{bench, section};
use dockerssd::config::{EtherOnConfig, SsdConfig};
use dockerssd::coordinator::{Batcher, InferenceRequest, Router};
use dockerssd::etheron::{EthFrame, EtherType, EtherOnDriver, MacAddr, TcpSegment, TcpFlags, TcpStack};
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::nvme::{BlockBackend, FrameSink, NvmeCommand, NvmeController, NvmeSubsystem, PcieFunction, QueuePair};
use dockerssd::ssd::SsdDevice;
use dockerssd::util::SimTime;

struct NullBackend;
impl BlockBackend for NullBackend {
    fn read(&mut self, at: SimTime, _lba: u64, blocks: u64) -> (SimTime, Vec<u8>) {
        (at, vec![0; (blocks * 512) as usize])
    }
    fn write(&mut self, at: SimTime, _lba: u64, _data: &[u8]) -> SimTime {
        at
    }
    fn flush(&mut self, at: SimTime) -> SimTime {
        at
    }
}

struct NullSink;
impl FrameSink for NullSink {
    fn deliver(&mut self, _at: SimTime, _frame: &[u8]) -> SimTime {
        SimTime::us(1)
    }
}

fn main() {
    section("NVMe");
    let mut ctl = NvmeController::new(NvmeSubsystem::standard(1_000_000, 0.3));
    let mut qp = QueuePair::new(1, 64);
    let mut be = NullBackend;
    let mut sink = NullSink;
    bench("service_queue: 32 reads", || {
        for i in 0..32u16 {
            qp.sq.submit(NvmeCommand::read(i, 2, (i as u64) * 8, 7)).unwrap();
        }
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        while qp.cq.reap().is_some() {}
    });

    section("Ether-oN");
    let mut drv = EtherOnDriver::new(EtherOnConfig::default());
    let mut qp2 = QueuePair::new(2, 64);
    drv.arm_upcalls(&mut qp2);
    ctl.service_queue(SimTime::ZERO, &mut qp2, PcieFunction::Host, &mut be, &mut sink);
    let frame = EthFrame {
        dst: MacAddr::for_node(1),
        src: MacAddr::for_node(0),
        ethertype: EtherType::Ipv4,
        payload: vec![0xAB; 1024],
    };
    bench("frame encode+decode (1KB)", || {
        let bytes = frame.encode();
        std::hint::black_box(EthFrame::decode(&bytes).unwrap());
    });
    bench("tx+rx round trip via upcall", || {
        drv.transmit(&mut qp2, &frame).unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp2, PcieFunction::Host, &mut be, &mut sink);
        ctl.upcall(&mut qp2, frame.encode());
        std::hint::black_box(drv.poll_rx(&mut qp2));
    });

    section("TCP FSM");
    bench("handshake + 1KB data + teardown", || {
        let mut client = TcpStack::new();
        let mut server = TcpStack::new();
        server.listen(2375);
        let server_ip = Ipv4Addr::new(10, 77, 0, 2);
        let client_ip = Ipv4Addr::new(10, 77, 0, 1);
        let syn = client.connect(49152, server_ip, 2375);
        let syn_ack = server.process(client_ip, &syn);
        let ack = client.process(server_ip, &syn_ack[0]);
        server.process(client_ip, &ack[0]);
        let seg = client.send((49152, server_ip, 2375), vec![0u8; 1024]).unwrap();
        server.process(client_ip, &seg);
        std::hint::black_box(server.recv((2375, client_ip, 49152)));
    });
    bench("tcp segment encode+decode (1KB)", || {
        let seg = TcpSegment {
            src_port: 1,
            dst_port: 2,
            seq: 100,
            ack: 200,
            flags: TcpFlags::ACK,
            window: 65535,
            payload: vec![7u8; 1024],
        };
        std::hint::black_box(TcpSegment::decode(&seg.encode()).unwrap());
    });

    section("SSD backend");
    let mut dev = SsdDevice::new(SsdConfig::default());
    let mut page = 0u64;
    bench("write_pages (fresh page, ICL+FTL)", || {
        dev.write_pages(SimTime::ZERO, page % 100_000, 1);
        page += 1;
    });
    bench("read_pages (hot page, ICL hit)", || {
        std::hint::black_box(dev.read_pages(SimTime::ZERO, 42, 1));
    });

    section("lambda-FS");
    let mut dev2 = SsdDevice::new(SsdConfig::default());
    let mut fs = LambdaFs::over_device(&dev2);
    for i in 0..100 {
        fs.write_file(&mut dev2, SimTime::ZERO, &format!("/data/d{}/f{}", i % 10, i), b"x", LockSide::Isp)
            .ok();
    }
    bench("path walk (cached)", || {
        std::hint::black_box(fs.walk("/data/d3/f33").unwrap());
    });
    bench("4KB file read", || {
        std::hint::black_box(fs.read_file(&mut dev2, SimTime::ZERO, "/data/d3/f33", LockSide::Isp).unwrap());
    });

    section("coordinator");
    let mut router = Router::new(16);
    bench("router pick+complete", || {
        let n = router.pick();
        router.complete(n);
    });
    bench("batcher push+form (width 4)", || {
        let mut b = Batcher::new(4, 32, SimTime::ZERO);
        for id in 0..4 {
            b.push(
                InferenceRequest {
                    id,
                    prompt: vec![1; 32],
                    max_new_tokens: 8,
                },
                SimTime::ZERO,
            );
        }
        std::hint::black_box(b.form(SimTime::ZERO, false).unwrap());
    });

    section("JSON");
    let manifest = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest {
        bench("manifest.json parse", || {
            std::hint::black_box(dockerssd::json::parse(&text).unwrap());
        });
    }
}
