//! Substrate hot-path microbenchmarks (§Perf, L3): the pieces that sit
//! on the simulated request path — the calendar event queue, the WFQ
//! fabric engine, NVMe queue service, Ether-oN frame round-trip, flash
//! timing model, FTL mapping, λFS path walk, TCP segment processing,
//! JSON manifest parse, batcher/router.
//!
//! Emits `BENCH_substrate_hotpath.json` for the sections on the
//! millions-of-events/sec path (event queue, WFQ engine); wall-clock
//! ns/op figures, reported as new benches until a CI-runner baseline
//! is committed.

use std::net::Ipv4Addr;

use dockerssd::benchkit::{bench, emit_json, section, BenchRecord};
use dockerssd::config::{EtherOnConfig, PoolConfig, SsdConfig};
use dockerssd::coordinator::{Batcher, InferenceRequest, Router};
use dockerssd::etheron::{EthFrame, EtherType, EtherOnDriver, MacAddr, TcpSegment, TcpFlags, TcpStack};
use dockerssd::fabric::{Endpoint, Fabric, Priority};
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::nvme::{BlockBackend, FrameSink, NvmeCommand, NvmeController, NvmeSubsystem, PcieFunction, QueuePair};
use dockerssd::sim::EventQueue;
use dockerssd::ssd::SsdDevice;
use dockerssd::util::{Rng, SimTime};

struct NullBackend;
impl BlockBackend for NullBackend {
    fn read(&mut self, at: SimTime, _lba: u64, blocks: u64) -> (SimTime, Vec<u8>) {
        (at, vec![0; (blocks * 512) as usize])
    }
    fn write(&mut self, at: SimTime, _lba: u64, _data: &[u8]) -> SimTime {
        at
    }
    fn flush(&mut self, at: SimTime) -> SimTime {
        at
    }
}

struct NullSink;
impl FrameSink for NullSink {
    fn deliver(&mut self, _at: SimTime, _frame: &[u8]) -> SimTime {
        SimTime::us(1)
    }
}

fn main() {
    let mut records = Vec::new();

    section("event queue");
    // steady-state churn: the queue holds 4k pending events (a busy
    // mid-replay pool) and every op pops the next event and reschedules
    // it a sub-millisecond hop ahead — the calendar ring's fast path
    let mut q = EventQueue::new();
    let mut rng = Rng::new(7);
    for i in 0..4096u64 {
        q.schedule_at(SimTime::ns(1 + rng.below(4_000_000)), i);
    }
    let r = bench("pop+reschedule churn (4k deep, near-future)", || {
        for _ in 0..64 {
            let ev = q.pop().unwrap();
            q.schedule_at(ev.at + SimTime::ns(1 + rng.below(1_000_000)), ev.tag);
        }
    });
    records.push(BenchRecord::new(
        "event_queue_churn_4k",
        "ns_per_op",
        r.mean.as_nanos() as f64 / 64.0,
    ));
    // far-future reschedules land beyond the ring span, exercising the
    // overflow heap and its migration back into the ring
    let r = bench("pop+reschedule churn (4k deep, 10ms ahead)", || {
        for _ in 0..64 {
            let ev = q.pop().unwrap();
            q.schedule_at(ev.at + SimTime::ms(10), ev.tag);
        }
    });
    records.push(BenchRecord::new(
        "event_queue_churn_4k_overflow",
        "ns_per_op",
        r.mean.as_nanos() as f64 / 64.0,
    ));

    section("WFQ engine");
    // 64 flights contend for two arrays' links, 1:3 fg:bg, drained to
    // idle — grant evaluation cost is O(active flights), not O(pool)
    let pcfg = PoolConfig {
        nodes_per_array: 8,
        arrays: 2,
        ..Default::default()
    };
    let ecfg = EtherOnConfig::default();
    let r = bench("64 contending flights, run_to_idle", || {
        let mut f = Fabric::new(&pcfg, &ecfg);
        for i in 0..64u32 {
            let pri = if i % 4 == 0 { Priority::Foreground } else { Priority::Background };
            f.schedule(
                SimTime::ZERO,
                Endpoint::Node(i % 16),
                Endpoint::Node((i + 7) % 16),
                1 << 16,
                pri,
            );
        }
        std::hint::black_box(f.run_to_idle());
    });
    records.push(BenchRecord::new(
        "wfq_64_flights_to_idle",
        "ns_per_flight",
        r.mean.as_nanos() as f64 / 64.0,
    ));

    section("NVMe");
    let mut ctl = NvmeController::new(NvmeSubsystem::standard(1_000_000, 0.3));
    let mut qp = QueuePair::new(1, 64);
    let mut be = NullBackend;
    let mut sink = NullSink;
    bench("service_queue: 32 reads", || {
        for i in 0..32u16 {
            qp.sq.submit(NvmeCommand::read(i, 2, (i as u64) * 8, 7)).unwrap();
        }
        ctl.service_queue(SimTime::ZERO, &mut qp, PcieFunction::Host, &mut be, &mut sink);
        while qp.cq.reap().is_some() {}
    });

    section("Ether-oN");
    let mut drv = EtherOnDriver::new(EtherOnConfig::default());
    let mut qp2 = QueuePair::new(2, 64);
    drv.arm_upcalls(&mut qp2);
    ctl.service_queue(SimTime::ZERO, &mut qp2, PcieFunction::Host, &mut be, &mut sink);
    let frame = EthFrame {
        dst: MacAddr::for_node(1),
        src: MacAddr::for_node(0),
        ethertype: EtherType::Ipv4,
        payload: vec![0xAB; 1024],
    };
    bench("frame encode+decode (1KB)", || {
        let bytes = frame.encode();
        std::hint::black_box(EthFrame::decode(&bytes).unwrap());
    });
    bench("tx+rx round trip via upcall", || {
        drv.transmit(&mut qp2, &frame).unwrap();
        ctl.service_queue(SimTime::ZERO, &mut qp2, PcieFunction::Host, &mut be, &mut sink);
        ctl.upcall(&mut qp2, frame.encode());
        std::hint::black_box(drv.poll_rx(&mut qp2));
    });

    section("TCP FSM");
    bench("handshake + 1KB data + teardown", || {
        let mut client = TcpStack::new();
        let mut server = TcpStack::new();
        server.listen(2375);
        let server_ip = Ipv4Addr::new(10, 77, 0, 2);
        let client_ip = Ipv4Addr::new(10, 77, 0, 1);
        let syn = client.connect(49152, server_ip, 2375);
        let syn_ack = server.process(client_ip, &syn);
        let ack = client.process(server_ip, &syn_ack[0]);
        server.process(client_ip, &ack[0]);
        let seg = client.send((49152, server_ip, 2375), vec![0u8; 1024]).unwrap();
        server.process(client_ip, &seg);
        std::hint::black_box(server.recv((2375, client_ip, 49152)));
    });
    bench("tcp segment encode+decode (1KB)", || {
        let seg = TcpSegment {
            src_port: 1,
            dst_port: 2,
            seq: 100,
            ack: 200,
            flags: TcpFlags::ACK,
            window: 65535,
            payload: vec![7u8; 1024],
        };
        std::hint::black_box(TcpSegment::decode(&seg.encode()).unwrap());
    });

    section("SSD backend");
    let mut dev = SsdDevice::new(SsdConfig::default());
    let mut page = 0u64;
    bench("write_pages (fresh page, ICL+FTL)", || {
        dev.write_pages(SimTime::ZERO, page % 100_000, 1);
        page += 1;
    });
    bench("read_pages (hot page, ICL hit)", || {
        std::hint::black_box(dev.read_pages(SimTime::ZERO, 42, 1));
    });

    section("lambda-FS");
    let mut dev2 = SsdDevice::new(SsdConfig::default());
    let mut fs = LambdaFs::over_device(&dev2);
    for i in 0..100 {
        fs.write_file(&mut dev2, SimTime::ZERO, &format!("/data/d{}/f{}", i % 10, i), b"x", LockSide::Isp)
            .ok();
    }
    bench("path walk (cached)", || {
        std::hint::black_box(fs.walk("/data/d3/f33").unwrap());
    });
    bench("4KB file read", || {
        std::hint::black_box(fs.read_file(&mut dev2, SimTime::ZERO, "/data/d3/f33", LockSide::Isp).unwrap());
    });

    section("coordinator");
    let mut router = Router::new(16);
    bench("router pick+complete", || {
        let n = router.pick();
        router.complete(n);
    });
    bench("batcher push+form (width 4)", || {
        let mut b = Batcher::new(4, 32, SimTime::ZERO);
        for id in 0..4 {
            b.push(
                InferenceRequest {
                    id,
                    prompt: vec![1; 32],
                    max_new_tokens: 8,
                },
                SimTime::ZERO,
            );
        }
        std::hint::black_box(b.form(SimTime::ZERO, false).unwrap());
    });

    section("JSON");
    let manifest = std::fs::read_to_string("artifacts/manifest.json").ok();
    if let Some(text) = manifest {
        bench("manifest.json parse", || {
            std::hint::black_box(dockerssd::json::parse(&text).unwrap());
        });
    }

    emit_json("BENCH_substrate_hotpath.json", &records).expect("write BENCH_substrate_hotpath.json");
}
