"""L1 Pallas kernel: KV-cache decode attention (the DockerSSD ISP hot spot).

The paper's case study serves distributed LLM inference from a
computing-enabled storage pool, where each DockerSSD keeps the KV cache on
flash it can address "as local memory".  The per-token decode attention is
the memory-bound hot spot: one new query row is scored against the whole
cached K/V history.

Hardware adaptation (GPU paper -> TPU kernel, see DESIGN.md
section Hardware-Adaptation): instead of a warp-per-row flash-decoding
kernel over HBM, we stream the KV cache through VMEM in blocks along the
grid's innermost axis and keep an online-softmax carry (running max, running
denominator, weighted accumulator) in VMEM scratch.  The full S x S attention
matrix is never materialized; VMEM holds exactly one (block_kv, head_dim)
K tile and V tile plus the O(head_dim) carry.

The kernel is always constructed with ``interpret=True``: the CPU PJRT
client cannot execute Mosaic custom-calls, and the AOT path (python/compile/
aot.py) needs plain-HLO lowering so the Rust runtime can run it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default KV block: 128 rows keeps the K/V tiles aligned to the 128-lane
# vector register shape while bounding VMEM to 2 * 128 * head_dim * 4B of
# tile traffic per grid step (~32KB for head_dim=32).
DEFAULT_BLOCK_KV = 128

NEG_INF = -1e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_kv: int):
    """One (batch, head, kv-block) grid step of online-softmax attention.

    Block views configured by the BlockSpecs in :func:`decode_attention`:
      pos_ref: [1]                       valid cache length
      q_ref:   [1, 1, head_dim]          the new query row for this (b, h)
      k_ref:   [1, 1, block_kv, head_dim]
      v_ref:   [1, 1, block_kv, head_dim]
      o_ref:   [1, 1, head_dim]
      m/l/acc: VMEM scratch carrying online-softmax state across kv blocks
    """
    blk = pl.program_id(2)
    num_blocks = pl.num_programs(2)

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0, 0, :].astype(jnp.float32)       # [head_dim]
    k = k_ref[0, 0].astype(jnp.float32)          # [block_kv, head_dim]
    v = v_ref[0, 0].astype(jnp.float32)          # [block_kv, head_dim]

    # Scores for this block of cached keys; rows at index >= pos are padding.
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.dot(k, q) * scale                    # [block_kv]
    offs = blk * block_kv + jax.lax.iota(jnp.int32, block_kv)
    s = jnp.where(offs < pos, s, NEG_INF)

    # Online-softmax (flash-decoding) recurrence.
    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_cur)                       # [block_kv]
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[0] = m_cur

    @pl.when(blk == num_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, block_kv: int = DEFAULT_BLOCK_KV):
    """Single-token decode attention against a KV cache.

    Args:
      q:        [batch, heads, head_dim] query rows for the new token.
      k_cache:  [batch, heads, max_seq, head_dim]
      v_cache:  [batch, heads, max_seq, head_dim]
      pos:      scalar int32 — number of valid cache rows (the new token's
                K/V must already be written at index ``pos - 1``).
      block_kv: KV rows streamed through VMEM per grid step.

    Returns:
      [batch, heads, head_dim] attention output, dtype of ``q``.
    """
    batch, heads, max_seq, head_dim = k_cache.shape
    if q.shape != (batch, heads, head_dim):
        raise ValueError(f"q shape {q.shape} != {(batch, heads, head_dim)}")
    block_kv = min(block_kv, max_seq)
    if max_seq % block_kv != 0:
        raise ValueError(f"max_seq={max_seq} not a multiple of block_kv={block_kv}")
    num_blocks = max_seq // block_kv
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    return pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_kv=block_kv),
        grid=(batch, heads, num_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (0,)),
            pl.BlockSpec((1, 1, head_dim), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, block_kv, head_dim), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_kv, head_dim), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, head_dim), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, heads, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((head_dim,), jnp.float32),
        ],
        interpret=True,
    )(pos_arr, q, k_cache, v_cache)


def vmem_footprint_bytes(head_dim: int, block_kv: int = DEFAULT_BLOCK_KV,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM bytes resident per grid step (DESIGN.md section Perf).

    One K tile + one V tile + q row + output row + the online-softmax carry.
    Used by the perf pass to verify the kernel stays VMEM-resident for long
    caches instead of scaling with max_seq.
    """
    tiles = 2 * block_kv * head_dim * dtype_bytes
    rows = 2 * head_dim * dtype_bytes
    carry = (2 + head_dim) * 4
    return tiles + rows + carry
