"""L1 Pallas kernel: fused feed-forward network (matmul -> bias -> GeLU -> matmul -> bias).

The transformer FFN is the MXU-bound half of the decode step.  On GPU the
paper's substrate would express this as two cuBLAS calls with an elementwise
kernel between them; on TPU we fuse the chain so the [rows, block_f]
intermediate activation lives only in VMEM and never round-trips to HBM.

Tiling: the hidden (ffn) dimension is split into ``block_f``-wide tiles on
the grid's innermost axis.  Each step computes

    h_blk = gelu(x @ w1[:, blk] + b1[blk])        # [rows, block_f], VMEM only
    acc  += h_blk @ w2[blk, :]                    # [rows, d_model] carry

so the MXU sees two dense (rows x d_model x block_f) contractions per step
and the accumulator is the only cross-step state.  block_f=128 matches the
MXU systolic tile edge.

interpret=True for CPU-PJRT executability (see attention.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_F = 128


def _gelu(x):
    # tanh-approximation GeLU, matching jax.nn.gelu(approximate=True).
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, acc_ref):
    """One hidden-dim tile of the fused FFN.

    Views: x [rows, d], w1 [d, block_f], b1 [block_f], w2 [block_f, d],
    b2 [d], o [rows, d], acc scratch [rows, d] (f32).
    """
    blk = pl.program_id(0)
    num_blocks = pl.num_programs(0)

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    h = _gelu(jnp.dot(x, w1) + b1_ref[...].astype(jnp.float32))
    acc_ref[...] += jnp.dot(h, w2_ref[...].astype(jnp.float32))

    @pl.when(blk == num_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] + b2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_ffn(x, w1, b1, w2, b2, *, block_f: int = DEFAULT_BLOCK_F):
    """Fused ``gelu(x @ w1 + b1) @ w2 + b2``.

    Args:
      x:  [rows, d_model]
      w1: [d_model, d_ff];  b1: [d_ff]
      w2: [d_ff, d_model];  b2: [d_model]
      block_f: hidden-dim tile width streamed through VMEM per grid step.

    Returns: [rows, d_model], dtype of ``x``.
    """
    rows, d_model = x.shape
    d_ff = w1.shape[1]
    if w1.shape != (d_model, d_ff) or w2.shape != (d_ff, d_model):
        raise ValueError(f"inconsistent FFN shapes: {w1.shape}, {w2.shape}")
    block_f = min(block_f, d_ff)
    if d_ff % block_f != 0:
        raise ValueError(f"d_ff={d_ff} not a multiple of block_f={block_f}")
    num_blocks = d_ff // block_f

    return pl.pallas_call(
        _ffn_kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((rows, d_model), lambda f: (0, 0)),
            pl.BlockSpec((d_model, block_f), lambda f: (0, f)),
            pl.BlockSpec((block_f,), lambda f: (f,)),
            pl.BlockSpec((block_f, d_model), lambda f: (f, 0)),
            pl.BlockSpec((d_model,), lambda f: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d_model), lambda f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_model), x.dtype),
        scratch_shapes=[pltpu.VMEM((rows, d_model), jnp.float32)],
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_footprint_bytes(rows: int, d_model: int, block_f: int = DEFAULT_BLOCK_F,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM bytes per grid step (x tile + weight tiles + carry)."""
    x_tile = rows * d_model * dtype_bytes
    w_tiles = 2 * d_model * block_f * dtype_bytes + (block_f + d_model) * dtype_bytes
    h_tile = rows * block_f * 4
    acc = rows * d_model * 4
    return x_tile + w_tiles + h_tile + acc


def mxu_flops(rows: int, d_model: int, d_ff: int) -> int:
    """MACs*2 issued to the MXU for one fused_ffn call (utilization estimate)."""
    return 2 * rows * d_model * d_ff * 2
