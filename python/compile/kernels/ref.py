"""Pure-jnp oracles for every Pallas kernel (correctness ground truth).

Each function mirrors the exact contract of its kernel counterpart with the
most literal jnp expression possible — no tiling, no online softmax, no
fusion — so pytest/hypothesis can assert_allclose kernel vs oracle across
shape and dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_decode_attention(q, k_cache, v_cache, pos):
    """Oracle for kernels.attention.decode_attention.

    q [B,H,D], k_cache/v_cache [B,H,S,D], pos scalar -> [B,H,D].
    """
    qf = q.astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(qf.shape[-1]))
    s = jnp.einsum("bhd,bhsd->bhs", qf, k) * scale
    mask = jnp.arange(k.shape[2]) < pos
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bhsd->bhd", p, v)
    return out.astype(q.dtype)


def ref_ffn(x, w1, b1, w2, b2):
    """Oracle for kernels.ffn.fused_ffn (tanh-approximate GeLU)."""
    x32 = x.astype(jnp.float32)
    h = jax.nn.gelu(x32 @ w1.astype(jnp.float32) + b1.astype(jnp.float32),
                    approximate=True)
    out = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return out.astype(x.dtype)


def ref_embed_bag(table, indices):
    """Oracle for kernels.embed.embed_bag."""
    gathered = table.astype(jnp.float32)[indices]          # [B, bag, dim]
    return jnp.sum(gathered, axis=1).astype(table.dtype)
