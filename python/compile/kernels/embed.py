"""L1 Pallas kernel: embedding-bag lookup + aggregation (DLRM 'embed' workload).

Table 2's 'embed' workloads (rm1/rm2) perform DLRM embedding-table lookups
and aggregate sparse features — the canonical ISP workload the paper offloads
to DockerSSD (the table lives on flash; only the pooled vectors leave the
device).  This kernel is the in-storage compute for that path and backs the
``isp_workloads`` example's real-execution mode.

Tiling: grid over batch tiles; each step gathers ``bag`` rows for
``block_b`` bags from the table resident in ANY/HBM memory space and
segment-sums them in VMEM.  The gather is expressed with dynamic row loads
(pl.load on a dynamic slice), which interpret-mode executes directly and a
TPU lowering would turn into a DMA-gather per row.

interpret=True for CPU-PJRT executability (see attention.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8


def _embed_bag_kernel(idx_ref, table_ref, o_ref, *, bag: int, block_b: int):
    """Views: idx [block_b, bag] int32, table [n_rows, dim], o [block_b, dim]."""
    dim = o_ref.shape[-1]

    def body(i, acc):
        def inner(j, a):
            row = idx_ref[i, j]
            vec = table_ref[pl.dslice(row, 1), pl.dslice(0, dim)]
            return a + vec[0]

        pooled = jax.lax.fori_loop(0, bag, inner, jnp.zeros((dim,), jnp.float32))
        o_ref[i, :] = pooled.astype(o_ref.dtype)
        return acc

    jax.lax.fori_loop(0, block_b, body, 0)


def embed_bag(table, indices, *, block_b: int = DEFAULT_BLOCK_B):
    """Sum-pooled embedding lookup: ``out[b] = sum_j table[indices[b, j]]``.

    Args:
      table:   [n_rows, dim] float embedding table.
      indices: [batch, bag] int32 row ids, all in ``[0, n_rows)``.
      block_b: bags processed per grid step.

    Returns: [batch, dim], dtype of ``table``.
    """
    n_rows, dim = table.shape
    batch, bag = indices.shape
    block_b = min(block_b, batch)
    if batch % block_b != 0:
        raise ValueError(f"batch={batch} not a multiple of block_b={block_b}")
    num_blocks = batch // block_b

    return pl.pallas_call(
        functools.partial(_embed_bag_kernel, bag=bag, block_b=block_b),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, bag), lambda b: (b, 0)),
            pl.BlockSpec((n_rows, dim), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), table.dtype),
        interpret=True,
    )(indices.astype(jnp.int32), table)
