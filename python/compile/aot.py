"""AOT compile path: lower the L2 model to HLO *text* artifacts for Rust.

Run once at build time (``make artifacts``); the Rust coordinator then
loads and executes the artifacts through the PJRT C API without Python.

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ../artifacts):
  model_prefill.hlo.txt  — prefill(prompt) -> (logits, k_cache, v_cache)
  model_decode.hlo.txt   — decode_step(tokens, pos, kc, vc) -> (logits, kc', vc')
  embed_bag.hlo.txt      — DLRM embedding-bag kernel for the 'embed' workload
  weights.bin            — f32 little-endian params, concatenated in PARAM_ORDER
  manifest.json          — config, per-param offsets/shapes, argument orders

Argument order of the model executables (the Rust-side ABI):
  prefill: [prompt(i32)] + PARAM_ORDER
  decode:  [tokens(i32), pos(i32), k_cache, v_cache] + PARAM_ORDER
Outputs are always a flat tuple (return_tuple=True).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.embed import embed_bag
from compile.kernels.attention import vmem_footprint_bytes as attn_vmem
from compile.kernels.ffn import vmem_footprint_bytes as ffn_vmem, mxu_flops


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_model_artifacts(cfg: M.ModelConfig, out_dir: pathlib.Path, seed: int):
    params = M.init_weights(jax.random.PRNGKey(seed), cfg)
    order = M.PARAM_ORDER
    plist = [params[n] for n in order]

    # --- weights.bin + per-param manifest entries -------------------------
    offsets = []
    off = 0
    with open(out_dir / "weights.bin", "wb") as f:
        for name in order:
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            offsets.append({
                "name": name,
                "shape": list(arr.shape),
                "offset_bytes": off,
                "size_bytes": arr.nbytes,
            })
            off += arr.nbytes

    # --- prefill ----------------------------------------------------------
    def prefill_fn(prompt, *plist):
        p = dict(zip(order, plist))
        return M.prefill(p, cfg, prompt)

    prompt_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.prompt_len), jnp.int32)
    w_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in plist]
    lowered = jax.jit(prefill_fn).lower(prompt_spec, *w_specs)
    (out_dir / "model_prefill.hlo.txt").write_text(to_hlo_text(lowered))

    # --- decode step --------------------------------------------------------
    def decode_fn(tokens, pos, kc, vc, *plist):
        p = dict(zip(order, plist))
        return M.decode_step(p, cfg, tokens, pos, kc, vc)

    tok_spec = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    kv_spec = jax.ShapeDtypeStruct(cfg.kv_cache_shape(), jnp.float32)
    lowered = jax.jit(decode_fn).lower(tok_spec, pos_spec, kv_spec, kv_spec, *w_specs)
    (out_dir / "model_decode.hlo.txt").write_text(to_hlo_text(lowered))

    return offsets, off


def build_embed_artifact(out_dir: pathlib.Path, n_rows: int, dim: int,
                         batch: int, bag: int):
    """Standalone embedding-bag executable for the DLRM 'embed' ISP workload."""
    table_spec = jax.ShapeDtypeStruct((n_rows, dim), jnp.float32)
    idx_spec = jax.ShapeDtypeStruct((batch, bag), jnp.int32)
    lowered = jax.jit(lambda t, i: (embed_bag(t, i),)).lower(table_spec, idx_spec)
    (out_dir / "embed_bag.hlo.txt").write_text(to_hlo_text(lowered))
    return {"n_rows": n_rows, "dim": dim, "batch": batch, "bag": bag}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=20250710)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=8)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--embed-rows", type=int, default=4096)
    ap.add_argument("--embed-dim", type=int, default=64)
    ap.add_argument("--embed-batch", type=int, default=32)
    ap.add_argument("--embed-bag", type=int, default=16)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    cfg = M.ModelConfig(
        vocab=args.vocab, d_model=args.d_model, n_layers=args.n_layers,
        n_heads=args.n_heads, d_ff=args.d_ff, max_seq=args.max_seq,
        batch=args.batch, prompt_len=args.prompt_len,
    )
    print(f"[aot] model: {cfg} ({cfg.param_count():,} params)")

    offsets, total = build_model_artifacts(cfg, out_dir, args.seed)
    embed_cfg = build_embed_artifact(
        out_dir, args.embed_rows, args.embed_dim, args.embed_batch, args.embed_bag)

    weights_sha = hashlib.sha256((out_dir / "weights.bin").read_bytes()).hexdigest()
    manifest = {
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
            "batch": cfg.batch, "prompt_len": cfg.prompt_len,
            "head_dim": cfg.head_dim, "param_count": cfg.param_count(),
        },
        "seed": args.seed,
        "params": offsets,
        "weights_bytes": total,
        "weights_sha256": weights_sha,
        "param_order": M.PARAM_ORDER,
        "arg_order": {
            "prefill": ["prompt"] + M.PARAM_ORDER,
            "decode": ["tokens", "pos", "k_cache", "v_cache"] + M.PARAM_ORDER,
        },
        "outputs": {
            "prefill": ["logits", "k_cache", "v_cache"],
            "decode": ["logits", "k_cache", "v_cache"],
        },
        "embed_bag": embed_cfg,
        "artifacts": {
            "prefill": "model_prefill.hlo.txt",
            "decode": "model_decode.hlo.txt",
            "embed_bag": "embed_bag.hlo.txt",
            "weights": "weights.bin",
        },
        # DESIGN.md section Perf: analytic per-kernel VMEM/MXU estimates
        # (interpret-mode wallclock is not a TPU proxy).
        "perf_estimates": {
            "attn_vmem_bytes_per_step": attn_vmem(cfg.head_dim),
            "ffn_vmem_bytes_per_step": ffn_vmem(cfg.batch, cfg.d_model),
            "ffn_mxu_flops_per_call": mxu_flops(cfg.batch, cfg.d_model, cfg.d_ff),
        },
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))

    for name in ("model_prefill.hlo.txt", "model_decode.hlo.txt",
                 "embed_bag.hlo.txt", "weights.bin", "manifest.json"):
        sz = (out_dir / name).stat().st_size
        print(f"[aot] wrote {name}: {sz:,} bytes")


if __name__ == "__main__":
    main()
