"""L2 JAX model: decoder-only transformer with KV cache, built on the L1 kernels.

This is the model the DockerSSD storage pool serves in the paper's case
study (distributed LLM inference with per-device KV caching).  Two entry
points are AOT-lowered by aot.py and executed from the Rust coordinator:

  * :func:`prefill`     — run a fixed-length prompt, fill the KV cache, and
                          return the last-position logits.
  * :func:`decode_step` — one autoregressive token: append K/V at ``pos``,
                          run Pallas decode attention + fused FFN per layer,
                          return next-token logits and the updated cache.

Weights are *runtime inputs* (not baked constants) so the HLO stays small
and the Rust side performs a real model-load from ``artifacts/weights.bin``.
The canonical argument order is ``PARAM_ORDER``; aot.py records it in the
artifact manifest.

Python here is build-time only — never on the request path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention
from compile.kernels.ffn import fused_ffn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration for one AOT-compiled model variant."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    max_seq: int = 256
    batch: int = 4
    prompt_len: int = 32

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        return sum(math.prod(s) for _, s in param_shapes(self))

    def kv_cache_shape(self) -> Tuple[int, ...]:
        return (self.n_layers, self.batch, self.n_heads, self.max_seq, self.head_dim)


# Canonical parameter order — the ABI between aot.py and the Rust runtime.
# Per-layer tensors are stacked along a leading n_layers axis so the layer
# loop lowers to one lax.scan instead of n_layers copies of the body.
PARAM_ORDER: List[str] = [
    "tok_emb", "pos_emb",
    "ln1_s", "ln1_b", "wqkv", "bqkv", "wo", "bo",
    "ln2_s", "ln2_b", "w1", "b1", "w2", "b2",
    "lnf_s", "lnf_b",
]


def param_shapes(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every parameter, in PARAM_ORDER."""
    L, d, f, V, S = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
    return [
        ("tok_emb", (V, d)),
        ("pos_emb", (S, d)),
        ("ln1_s", (L, d)), ("ln1_b", (L, d)),
        ("wqkv", (L, d, 3 * d)), ("bqkv", (L, 3 * d)),
        ("wo", (L, d, d)), ("bo", (L, d)),
        ("ln2_s", (L, d)), ("ln2_b", (L, d)),
        ("w1", (L, d, f)), ("b1", (L, f)),
        ("w2", (L, f, d)), ("b2", (L, d)),
        ("lnf_s", (d,)), ("lnf_b", (d,)),
    ]


def init_weights(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """GPT-2-style initialization (scaled normal matrices, ones/zeros LN)."""
    params: Dict[str, jax.Array] = {}
    shapes = dict(param_shapes(cfg))
    keys = jax.random.split(key, len(PARAM_ORDER))
    for name, k in zip(PARAM_ORDER, keys):
        shape = shapes[name]
        if name in ("ln1_s", "ln2_s", "lnf_s"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("ln1_b", "ln2_b", "lnf_b", "bqkv", "bo", "b1", "b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name in ("tok_emb", "pos_emb"):
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
        else:
            fan_in = shape[-2]
            params[name] = jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)
    return params


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _split_heads(x, cfg: ModelConfig):
    # [batch, d_model] -> [batch, heads, head_dim]
    return x.reshape(x.shape[0], cfg.n_heads, cfg.head_dim)


def decode_step(params: Dict[str, jax.Array], cfg: ModelConfig,
                tokens: jax.Array, pos: jax.Array,
                k_cache: jax.Array, v_cache: jax.Array):
    """One autoregressive decode step for the whole batch.

    Args:
      params:  dict keyed per PARAM_ORDER.
      tokens:  [batch] int32 — the tokens at position ``pos`` whose
               successors we predict.
      pos:     scalar int32 — index where this token's K/V is written; the
               attention then sees ``pos + 1`` valid rows.
      k_cache: [n_layers, batch, heads, max_seq, head_dim]
      v_cache: same shape.

    Returns: (logits [batch, vocab], k_cache', v_cache').
    """
    B = cfg.batch
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]          # [B, d]

    layer_ws = (
        params["ln1_s"], params["ln1_b"], params["wqkv"], params["bqkv"],
        params["wo"], params["bo"], params["ln2_s"], params["ln2_b"],
        params["w1"], params["b1"], params["w2"], params["b2"],
    )

    def layer(carry, xs):
        x = carry
        (ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2,
         kc, vc) = xs
        h = _layernorm(x, ln1_s, ln1_b)
        qkv = h @ wqkv + bqkv                                       # [B, 3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(t, cfg) for t in (q, k, v))         # [B,H,Dh]
        # Append this token's K/V at row ``pos``.
        kc = jax.lax.dynamic_update_slice(kc, k[:, :, None, :], (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[:, :, None, :], (0, 0, pos, 0))
        attn = decode_attention(q, kc, vc, pos + 1)                 # [B,H,Dh]
        x = x + attn.reshape(B, cfg.d_model) @ wo + bo
        h2 = _layernorm(x, ln2_s, ln2_b)
        x = x + fused_ffn(h2, w1, b1, w2, b2)
        return x, (kc, vc)

    x, (k_cache, v_cache) = jax.lax.scan(layer, x, layer_ws + (k_cache, v_cache))
    x = _layernorm(x, params["lnf_s"], params["lnf_b"])
    logits = x @ params["tok_emb"].T                                # tied head
    return logits, k_cache, v_cache


def prefill(params: Dict[str, jax.Array], cfg: ModelConfig, prompt: jax.Array):
    """Process a fixed-length prompt, returning last-token logits + KV cache.

    Prefill is compute-bound and runs once per request, so it uses plain
    jnp causal attention (XLA fuses it well); the Pallas kernels own the
    per-token decode path, which dominates end-to-end serving time.

    Args:
      prompt: [batch, prompt_len] int32.

    Returns: (logits [batch, vocab], k_cache, v_cache) with caches shaped
      [n_layers, batch, heads, max_seq, head_dim]; rows [0, prompt_len) valid.
    """
    B, P, S = cfg.batch, cfg.prompt_len, cfg.max_seq
    H, Dh = cfg.n_heads, cfg.head_dim
    x = params["tok_emb"][prompt] + params["pos_emb"][:P][None, :, :]  # [B,P,d]

    layer_ws = (
        params["ln1_s"], params["ln1_b"], params["wqkv"], params["bqkv"],
        params["wo"], params["bo"], params["ln2_s"], params["ln2_b"],
        params["w1"], params["b1"], params["w2"], params["b2"],
    )
    causal = jnp.tril(jnp.ones((P, P), bool))

    def layer(x, xs):
        ln1_s, ln1_b, wqkv, bqkv, wo, bo, ln2_s, ln2_b, w1, b1, w2, b2 = xs
        h = _layernorm(x, ln1_s, ln1_b)
        qkv = h @ wqkv + bqkv                                        # [B,P,3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, P, H, Dh).transpose(0, 2, 1, 3)             # [B,H,P,Dh]
        k = k.reshape(B, P, H, Dh).transpose(0, 2, 1, 3)
        v = v.reshape(B, P, H, Dh).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(Dh))
        s = jnp.where(causal[None, None], s, -1e30)
        attn = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, P, cfg.d_model)
        x = x + attn @ wo + bo
        h2 = _layernorm(x, ln2_s, ln2_b)
        ff = jax.nn.gelu(h2 @ w1 + b1, approximate=True) @ w2 + b2
        x = x + ff
        # Cache K/V padded out to max_seq rows.
        pad = [(0, 0), (0, 0), (0, S - P), (0, 0)]
        return x, (jnp.pad(k, pad), jnp.pad(v, pad))

    x, (k_cache, v_cache) = jax.lax.scan(layer, x, layer_ws)
    x = _layernorm(x[:, -1, :], params["lnf_s"], params["lnf_b"])    # [B, d]
    logits = x @ params["tok_emb"].T
    return logits, k_cache, v_cache


def reference_decode_step(params, cfg: ModelConfig, tokens, pos, k_cache, v_cache):
    """Oracle decode step using only jnp (no Pallas), for pytest."""
    from compile.kernels.ref import ref_decode_attention, ref_ffn

    B = cfg.batch
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]

    for l in range(cfg.n_layers):
        h = _layernorm(x, params["ln1_s"][l], params["ln1_b"][l])
        qkv = h @ params["wqkv"][l] + params["bqkv"][l]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k, v = (_split_heads(t, cfg) for t in (q, k, v))
        kc = jax.lax.dynamic_update_slice(k_cache[l], k[:, :, None, :], (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[l], v[:, :, None, :], (0, 0, pos, 0))
        k_cache = k_cache.at[l].set(kc)
        v_cache = v_cache.at[l].set(vc)
        attn = ref_decode_attention(q, kc, vc, pos + 1)
        x = x + attn.reshape(B, cfg.d_model) @ params["wo"][l] + params["bo"][l]
        h2 = _layernorm(x, params["ln2_s"][l], params["ln2_b"][l])
        x = x + ref_ffn(h2, params["w1"][l], params["b1"][l],
                        params["w2"][l], params["b2"][l])

    x = _layernorm(x, params["lnf_s"], params["lnf_b"])
    return x @ params["tok_emb"].T, k_cache, v_cache
