"""Hypothesis shape/dtype sweeps: Pallas kernels vs pure-jnp oracles.

Strategy draws structurally valid shapes (power-of-two-ish dims, divisible
block sizes) and random positions/indices, then asserts allclose against
ref.py.  Deadlines are disabled: interpret-mode Pallas traces are slow on
the first call for each new shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention
from compile.kernels.embed import embed_bag
from compile.kernels.ffn import fused_ffn
from compile.kernels import ref

COMMON = dict(deadline=None, max_examples=20, print_blob=True)


def arrays(key, *shape, scale=1.0, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


@settings(**COMMON)
@given(
    batch=st.sampled_from([1, 2, 3]),
    heads=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([64, 128, 192, 256]),
    head_dim=st.sampled_from([8, 16, 32]),
    block_kv=st.sampled_from([32, 64, 128]),
    pos_frac=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_attention_matches_ref(batch, heads, seq, head_dim, block_kv,
                                      pos_frac, seed, dtype):
    if seq % block_kv != 0:
        block_kv = 32
    pos = max(1, int(pos_frac * seq))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = arrays(ks[0], batch, heads, head_dim, dtype=dtype)
    kc = arrays(ks[1], batch, heads, seq, head_dim, dtype=dtype)
    vc = arrays(ks[2], batch, heads, seq, head_dim, dtype=dtype)
    out = decode_attention(q, kc, vc, pos, block_kv=block_kv)
    want = ref.ref_decode_attention(q, kc, vc, pos)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@settings(**COMMON)
@given(
    rows=st.sampled_from([1, 2, 4, 8]),
    d_model=st.sampled_from([32, 64, 128]),
    d_ff=st.sampled_from([64, 128, 256, 512]),
    block_f=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_ffn_matches_ref(rows, d_model, d_ff, block_f, seed):
    if d_ff % block_f != 0:
        block_f = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = arrays(ks[0], rows, d_model)
    w1 = arrays(ks[1], d_model, d_ff, scale=0.1)
    b1 = arrays(ks[2], d_ff)
    w2 = arrays(ks[3], d_ff, d_model, scale=0.1)
    b2 = arrays(ks[4], d_model)
    out = fused_ffn(x, w1, b1, w2, b2, block_f=block_f)
    want = ref.ref_ffn(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


@settings(**COMMON)
@given(
    n_rows=st.sampled_from([16, 100, 1024]),
    dim=st.sampled_from([4, 16, 64]),
    batch=st.sampled_from([4, 8, 16, 32]),
    bag=st.sampled_from([1, 2, 8, 16]),
    block_b=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_embed_bag_matches_ref(n_rows, dim, batch, bag, block_b, seed):
    if batch % block_b != 0:
        block_b = 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    table = arrays(ks[0], n_rows, dim)
    idx = jax.random.randint(ks[1], (batch, bag), 0, n_rows)
    out = embed_bag(table, idx, block_b=block_b)
    want = ref.ref_embed_bag(table, idx)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)
