"""AOT artifact tests: manifest integrity and HLO lowering round-trip."""

import json
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

needs_artifacts = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="run `make artifacts` first",
)


def test_to_hlo_text_small_function():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_to_hlo_text_pallas_kernel_lowers_to_plain_hlo():
    from compile.kernels.ffn import fused_ffn
    d, f = 32, 64
    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for s in [(2, d), (d, f), (f,), (f, d), (d,)]]
    lowered = jax.jit(lambda *a: (fused_ffn(*a),)).lower(*specs)
    text = aot.to_hlo_text(lowered)
    # interpret=True must not leave backend custom-calls behind
    assert "mosaic" not in text.lower()
    assert "HloModule" in text


@needs_artifacts
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        return json.loads((ART / "manifest.json").read_text())

    def test_param_order_matches_model(self, manifest):
        assert manifest["param_order"] == M.PARAM_ORDER

    def test_offsets_contiguous(self, manifest):
        off = 0
        for p in manifest["params"]:
            assert p["offset_bytes"] == off
            off += p["size_bytes"]
        assert off == manifest["weights_bytes"]

    def test_weights_file_size(self, manifest):
        assert (ART / "weights.bin").stat().st_size == manifest["weights_bytes"]

    def test_shapes_match_config(self, manifest):
        c = manifest["config"]
        cfg = M.ModelConfig(
            vocab=c["vocab"], d_model=c["d_model"], n_layers=c["n_layers"],
            n_heads=c["n_heads"], d_ff=c["d_ff"], max_seq=c["max_seq"],
            batch=c["batch"], prompt_len=c["prompt_len"])
        want = dict(M.param_shapes(cfg))
        for p in manifest["params"]:
            assert tuple(p["shape"]) == want[p["name"]], p["name"]

    def test_artifact_files_exist(self, manifest):
        for f in manifest["artifacts"].values():
            assert (ART / f).exists(), f

    def test_weights_reproducible_from_seed(self, manifest):
        """weights.bin must be exactly init_weights(seed) in PARAM_ORDER."""
        c = manifest["config"]
        cfg = M.ModelConfig(
            vocab=c["vocab"], d_model=c["d_model"], n_layers=c["n_layers"],
            n_heads=c["n_heads"], d_ff=c["d_ff"], max_seq=c["max_seq"],
            batch=c["batch"], prompt_len=c["prompt_len"])
        params = M.init_weights(jax.random.PRNGKey(manifest["seed"]), cfg)
        blob = (ART / "weights.bin").read_bytes()
        for p in manifest["params"]:
            arr = np.frombuffer(
                blob[p["offset_bytes"]:p["offset_bytes"] + p["size_bytes"]],
                dtype="<f4").reshape(p["shape"])
            np.testing.assert_allclose(arr, params[p["name"]], rtol=0, atol=0)

    def test_hlo_artifacts_have_expected_entry(self, manifest):
        for key in ("prefill", "decode", "embed_bag"):
            text = (ART / manifest["artifacts"][key]).read_text()
            assert text.startswith("HloModule"), key
            assert "mosaic" not in text.lower(), key
