"""L2 model tests: decode step vs reference, prefill/decode consistency, shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=4, d_ff=64,
                    max_seq=64, batch=2, prompt_len=8)


@pytest.fixture(scope="module")
def params():
    return M.init_weights(jax.random.PRNGKey(0), CFG)


def empty_cache():
    return (jnp.zeros(CFG.kv_cache_shape(), jnp.float32),
            jnp.zeros(CFG.kv_cache_shape(), jnp.float32))


class TestShapes:
    def test_param_shapes_cover_order(self):
        names = [n for n, _ in M.param_shapes(CFG)]
        assert names == M.PARAM_ORDER

    def test_param_count_matches_arrays(self, params):
        total = sum(int(np.prod(p.shape)) for p in params.values())
        assert total == CFG.param_count()

    def test_decode_output_shapes(self, params):
        kc, vc = empty_cache()
        toks = jnp.zeros((CFG.batch,), jnp.int32)
        logits, kc2, vc2 = M.decode_step(params, CFG, toks, jnp.int32(0), kc, vc)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kc2.shape == CFG.kv_cache_shape()
        assert vc2.shape == CFG.kv_cache_shape()

    def test_prefill_output_shapes(self, params):
        prompt = jnp.zeros((CFG.batch, CFG.prompt_len), jnp.int32)
        logits, kc, vc = M.prefill(params, CFG, prompt)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kc.shape == CFG.kv_cache_shape()


class TestDecodeCorrectness:
    def test_decode_matches_reference(self, params):
        kc, vc = empty_cache()
        key = jax.random.PRNGKey(7)
        toks = jax.random.randint(key, (CFG.batch,), 0, CFG.vocab)
        # run a few steps through both implementations, comparing each
        r_kc, r_vc = kc, vc
        for pos in range(4):
            logits, kc, vc = M.decode_step(params, CFG, toks, jnp.int32(pos), kc, vc)
            r_logits, r_kc, r_vc = M.reference_decode_step(
                params, CFG, toks, jnp.int32(pos), r_kc, r_vc)
            np.testing.assert_allclose(logits, r_logits, rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(kc, r_kc, rtol=1e-5, atol=1e-5)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)

    def test_cache_rows_written_at_pos(self, params):
        kc, vc = empty_cache()
        toks = jnp.ones((CFG.batch,), jnp.int32)
        _, kc2, _ = M.decode_step(params, CFG, toks, jnp.int32(5), kc, vc)
        # only row 5 should be nonzero
        assert float(jnp.abs(kc2[:, :, :, 5, :]).sum()) > 0
        untouched = jnp.concatenate([kc2[:, :, :, :5, :], kc2[:, :, :, 6:, :]], axis=3)
        assert float(jnp.abs(untouched).sum()) == 0.0


class TestPrefillDecodeConsistency:
    def test_prefill_equals_tokenwise_decode(self, params):
        """Prefilling P tokens must equal P sequential decode steps."""
        key = jax.random.PRNGKey(3)
        prompt = jax.random.randint(key, (CFG.batch, CFG.prompt_len), 0, CFG.vocab)
        p_logits, p_kc, p_vc = M.prefill(params, CFG, prompt)

        kc, vc = empty_cache()
        for pos in range(CFG.prompt_len):
            logits, kc, vc = M.decode_step(
                params, CFG, prompt[:, pos], jnp.int32(pos), kc, vc)

        np.testing.assert_allclose(logits, p_logits, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(kc[:, :, :, :CFG.prompt_len, :],
                                   p_kc[:, :, :, :CFG.prompt_len, :],
                                   rtol=5e-4, atol=5e-4)

    def test_generation_deterministic(self, params):
        prompt = jnp.zeros((CFG.batch, CFG.prompt_len), jnp.int32)

        def generate():
            logits, kc, vc = M.prefill(params, CFG, prompt)
            toks = []
            t = jnp.argmax(logits, -1).astype(jnp.int32)
            for i in range(5):
                toks.append(np.asarray(t))
                logits, kc, vc = M.decode_step(
                    params, CFG, t, jnp.int32(CFG.prompt_len + i), kc, vc)
                t = jnp.argmax(logits, -1).astype(jnp.int32)
            return np.stack(toks)

        a, b = generate(), generate()
        np.testing.assert_array_equal(a, b)
