"""Kernel-vs-oracle correctness: the core L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in ref.py with
assert_allclose, across deterministic cases here and hypothesis-driven
shape/dtype sweeps in test_kernel_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import decode_attention, vmem_footprint_bytes
from compile.kernels.embed import embed_bag
from compile.kernels.ffn import fused_ffn
from compile.kernels import ref


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


class TestDecodeAttention:
    @pytest.mark.parametrize("pos", [1, 7, 100, 255, 256])
    def test_matches_ref_across_pos(self, pos):
        k = jax.random.split(jax.random.PRNGKey(pos), 3)
        B, H, S, D = 2, 4, 256, 32
        q = rand(k[0], B, H, D)
        kc = rand(k[1], B, H, S, D)
        vc = rand(k[2], B, H, S, D)
        out = decode_attention(q, kc, vc, pos)
        want = ref.ref_decode_attention(q, kc, vc, pos)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("block_kv", [32, 64, 128, 256])
    def test_block_size_invariance(self, block_kv):
        k = jax.random.split(jax.random.PRNGKey(1), 3)
        B, H, S, D = 1, 2, 256, 16
        q = rand(k[0], B, H, D)
        kc = rand(k[1], B, H, S, D)
        vc = rand(k[2], B, H, S, D)
        out = decode_attention(q, kc, vc, 200, block_kv=block_kv)
        want = ref.ref_decode_attention(q, kc, vc, 200)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_pos_one_attends_only_first_row(self):
        k = jax.random.split(jax.random.PRNGKey(2), 3)
        B, H, S, D = 1, 1, 128, 8
        q = rand(k[0], B, H, D)
        kc = rand(k[1], B, H, S, D)
        vc = rand(k[2], B, H, S, D)
        out = decode_attention(q, kc, vc, 1)
        # softmax over a single valid row == that row's V exactly
        np.testing.assert_allclose(out[0, 0], vc[0, 0, 0], rtol=1e-6, atol=1e-6)

    def test_padding_rows_are_ignored(self):
        k = jax.random.split(jax.random.PRNGKey(3), 3)
        B, H, S, D = 1, 2, 128, 16
        q = rand(k[0], B, H, D)
        kc = rand(k[1], B, H, S, D)
        vc = rand(k[2], B, H, S, D)
        pos = 40
        out1 = decode_attention(q, kc, vc, pos)
        # Garbage beyond pos must not change the result.
        kc2 = kc.at[:, :, pos:, :].set(1e4)
        vc2 = vc.at[:, :, pos:, :].set(-1e4)
        out2 = decode_attention(q, kc2, vc2, pos)
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)

    def test_mismatched_q_shape_raises(self):
        q = jnp.zeros((2, 3, 8))
        kc = jnp.zeros((2, 4, 64, 8))
        with pytest.raises(ValueError):
            decode_attention(q, kc, kc, 1)

    def test_non_divisible_block_raises(self):
        q = jnp.zeros((1, 1, 8))
        kc = jnp.zeros((1, 1, 100, 8))
        with pytest.raises(ValueError):
            decode_attention(q, kc, kc, 1, block_kv=64)

    def test_vmem_footprint_is_seq_independent(self):
        # The whole point of block-streaming: VMEM cost does not grow with S.
        f = vmem_footprint_bytes(head_dim=64)
        assert f == vmem_footprint_bytes(head_dim=64)
        assert f < 4 * 1024 * 1024  # comfortably under one VMEM bank


class TestFusedFFN:
    @pytest.mark.parametrize("rows,d,f", [(1, 64, 128), (4, 256, 1024), (8, 128, 512)])
    def test_matches_ref(self, rows, d, f):
        k = jax.random.split(jax.random.PRNGKey(rows * d), 5)
        x = rand(k[0], rows, d)
        w1 = rand(k[1], d, f) * 0.1
        b1 = rand(k[2], f)
        w2 = rand(k[3], f, d) * 0.1
        b2 = rand(k[4], d)
        out = fused_ffn(x, w1, b1, w2, b2)
        want = ref.ref_ffn(x, w1, b1, w2, b2)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("block_f", [64, 128, 256, 512])
    def test_block_size_invariance(self, block_f):
        k = jax.random.split(jax.random.PRNGKey(9), 5)
        x = rand(k[0], 2, 128)
        w1 = rand(k[1], 128, 512) * 0.1
        b1 = rand(k[2], 512)
        w2 = rand(k[3], 512, 128) * 0.1
        b2 = rand(k[4], 128)
        out = fused_ffn(x, w1, b1, w2, b2, block_f=block_f)
        want = ref.ref_ffn(x, w1, b1, w2, b2)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_zero_input_gives_bias_path(self):
        d, f = 32, 64
        x = jnp.zeros((2, d))
        w1 = jnp.ones((d, f))
        b1 = jnp.zeros((f,))
        w2 = jnp.ones((f, d))
        b2 = jnp.full((d,), 3.0)
        # gelu(0) = 0, so out = b2 everywhere.
        np.testing.assert_allclose(fused_ffn(x, w1, b1, w2, b2),
                                   jnp.broadcast_to(b2, (2, d)), atol=1e-6)

    def test_inconsistent_shapes_raise(self):
        with pytest.raises(ValueError):
            fused_ffn(jnp.zeros((2, 8)), jnp.zeros((8, 16)), jnp.zeros((16,)),
                      jnp.zeros((8, 16)), jnp.zeros((8,)))


class TestEmbedBag:
    @pytest.mark.parametrize("batch,bag", [(8, 4), (32, 16), (16, 1)])
    def test_matches_ref(self, batch, bag):
        k = jax.random.split(jax.random.PRNGKey(batch), 2)
        table = rand(k[0], 1000, 64)
        idx = jax.random.randint(k[1], (batch, bag), 0, 1000)
        out = embed_bag(table, idx)
        want = ref.ref_embed_bag(table, idx)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    def test_repeated_index_counts_multiply(self):
        table = jnp.eye(4, dtype=jnp.float32)
        idx = jnp.array([[2, 2, 2, 2]], dtype=jnp.int32)
        out = embed_bag(table, idx)
        np.testing.assert_allclose(out[0], jnp.array([0, 0, 4, 0]), atol=1e-6)

    def test_non_divisible_batch_raises(self):
        with pytest.raises(ValueError):
            embed_bag(jnp.zeros((10, 4)), jnp.zeros((6, 2), jnp.int32), block_b=4)
