//! Quickstart: the DockerSSD workflow in one file.
//!
//! 1. Build a simulated DockerSSD (flash backend + λFS + Virtual-FW).
//! 2. Pull a container image over Ether-oN and run it (mini-docker).
//! 3. Let the ISP-container process a file near flash, protected by the
//!    inode-lock protocol.
//! 4. Read the result back from the host side.
//!
//! Run: `cargo run --release --example quickstart`

use dockerssd::config::SystemConfig;
use dockerssd::docker::{MiniDocker, Registry};
use dockerssd::firmware::VirtualFw;
use dockerssd::pool::WireRig;
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::ssd::SsdDevice;
use dockerssd::util::SimTime;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "DockerSSD: {} channels, {} packages, frontend {} cores @ {}GHz",
        cfg.ssd.channels,
        cfg.ssd.total_packages(),
        cfg.ssd.frontend_cores,
        cfg.ssd.frontend_ghz
    );

    // 1. the device: flash timing model + FTL + ICL, λFS on top
    let mut dev = SsdDevice::new(cfg.ssd.clone());
    let mut fs = LambdaFs::over_device(&dev);
    let mut fw = VirtualFw::new(&cfg.ssd);

    // 2. host stages input data into the sharable namespace
    let input: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
    let w = fs
        .write_file(&mut dev, SimTime::ZERO, "/data/input.bin", &input, LockSide::Host)
        .expect("host writes input");
    println!("host staged {} bytes into /data/input.bin ({:?} simulated)", input.len(), w.done);

    // 3. pull + run the ISP container (registry bytes cross the pool fabric)
    let reg = Registry::with_benchmark_images();
    let mut md = MiniDocker::new();
    let mut rig = WireRig::new(&cfg.pool, &cfg.etheron);
    let pulled = md
        .pull(&mut fw, &mut fs, &mut dev, &reg, &mut rig.ctx(w.done), 0, "pattern")
        .unwrap();
    let run = md.run(&mut fw, &mut fs, &mut dev, pulled.done, "pattern").unwrap();
    let id = run.output.clone();
    println!("ISP-container {} running ({:?} simulated)", id, run.done);

    // 4. the container binds the file (inode lock), processes near flash
    let ino = fs.walk("/data/input.bin").unwrap();
    assert!(fs.locks.acquire(ino, LockSide::Isp), "ISP binds the input");
    let (data, t_read) = fw.isp_read(&mut fs, &mut dev, run.done, "/data/input.bin").unwrap();
    let count = data.iter().filter(|&&b| b == 42).count();
    let t_write = fw
        .isp_write(
            &mut fs,
            &mut dev,
            t_read,
            "/data/result.txt",
            format!("matches: {count}\n").as_bytes(),
        )
        .unwrap();
    fs.locks.release(ino, LockSide::Isp);
    md.log_line(&mut fs, &mut dev, t_write, &id, &format!("processed {} bytes", data.len())).unwrap();

    // 5. host reads the result from the sharable namespace
    let result = fs
        .read_file(&mut dev, t_write, "/data/result.txt", LockSide::Host)
        .unwrap();
    println!("result (read by host): {}", String::from_utf8_lossy(&result.value).trim());
    println!(
        "simulated end-to-end: {:?}; fw emulated {} syscalls; flash: {} reads / {} programs",
        result.done,
        fw.syscalls.total(),
        dev.flash.reads,
        dev.flash.programs
    );

    md.stop(&mut fw, &mut fs, &mut dev, result.done, &id).unwrap();
    println!("container stopped. quickstart OK");
}
