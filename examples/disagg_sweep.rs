//! Disaggregation design-space sweep: beyond the paper's figures, explore
//! how the D-Cache advantage moves with pool size, KV path bandwidth, and
//! model scale — the ablation DESIGN.md calls out for the storage-pool
//! design choices.
//!
//! Run: `cargo run --release --example disagg_sweep`

use dockerssd::llm::disagg::{evaluate_scenario, DisaggModel};
use dockerssd::llm::{all_llms, DeviceProfile};
use dockerssd::llm::parallelism::find_optimal;
use dockerssd::metrics::Table;

fn main() {
    let llms = all_llms();
    let gpt3 = &llms[1];

    // pool-size scaling at fixed 32K sequence
    println!("pool-size scaling (gpt3-175B, 32K seq):");
    let mut t = Table::new(vec!["nodes", "H-Cache total_s", "D-Cache total_s", "speedup"]);
    for nodes in [16u32, 32, 64, 128] {
        let h = evaluate_scenario(gpt3, DisaggModel::HostCache, nodes, 32_768, 1);
        let d = evaluate_scenario(gpt3, DisaggModel::DockerCache, nodes, 32_768, 1);
        if let (Some(h), Some(d)) = (h, d) {
            t.row(vec![
                format!("{nodes}"),
                format!("{:.0}", h.time().total()),
                format!("{:.0}", d.time().total()),
                format!("{:.1}x", h.time().total() / d.time().total()),
            ]);
        }
    }
    println!("{}", t.render());

    // KV-path bandwidth ablation: how fast must flash be for the win?
    println!("flash KV-path bandwidth ablation (gpt3-175B, 32 nodes, 32K seq):");
    let mut t = Table::new(vec!["flash_kv_GBps", "D-Cache total_s", "speedup vs H-Cache"]);
    let h = evaluate_scenario(gpt3, DisaggModel::HostCache, 32, 32_768, 1).unwrap();
    for bw_gbps in [1.0f64, 2.0, 4.0, 8.0, 16.0] {
        let mut dev = DeviceProfile::dockerssd();
        dev.kv_bw = bw_gbps * 1e9;
        if let Some(d) = find_optimal(gpt3, &dev, 32, 32_768, 1, true) {
            t.row(vec![
                format!("{bw_gbps}"),
                format!("{:.0}", d.time.total()),
                format!("{:.1}x", h.time().total() / d.time.total()),
            ]);
        }
    }
    println!("{}", t.render());

    // model-scale sweep at fixed pool
    println!("model scale at 128 nodes, 32K seq (D-Cache):");
    let mut t = Table::new(vec!["model", "parallelism", "compute_s", "memory_s", "total_s"]);
    for llm in &llms {
        if let Some(d) = evaluate_scenario(llm, DisaggModel::DockerCache, 128, 32_768, 1) {
            t.row(vec![
                llm.name.to_string(),
                d.choice.par.label(),
                format!("{:.0}", d.time().compute),
                format!("{:.0}", d.time().memory),
                format!("{:.0}", d.time().total()),
            ]);
        }
    }
    println!("{}", t.render());
    println!("disagg_sweep OK");
}
