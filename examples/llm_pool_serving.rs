//! End-to-end case study (DESIGN.md E9): serve a real model from a
//! disaggregated pool.
//!
//! This is the driver that proves all three layers compose:
//!   L1 Pallas decode-attention + fused-FFN kernels ->
//!   L2 JAX transformer, AOT-lowered to HLO text ->
//!   L3 Rust coordinator executing via PJRT across pool-node engines,
//!   with batching, routing, and KV accounting.
//!
//! Requires `make artifacts` first.  Tokens are real model outputs
//! (greedy decode over the AOT-compiled weights), not mocks.
//!
//! Run: `cargo run --release --example llm_pool_serving [nodes] [requests] [tokens]`

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nodes = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let requests = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let tokens = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);

    println!("=== DockerSSD disaggregated pool serving (real PJRT execution) ===");
    match dockerssd::examples_support::run_serve("artifacts", nodes, requests, tokens) {
        Ok(()) => println!("llm_pool_serving OK"),
        Err(e) => {
            eprintln!("error: {e:#}");
            eprintln!("hint: run `make artifacts` first");
            std::process::exit(1);
        }
    }
}
