//! ISP workload study: replay scaled Table 2 traces against the real
//! substrates (λFS + flash timing + TCP), then compare the six
//! data-processing models on the full workload set — the experiment
//! behind Figures 3 and 11.
//!
//! Run: `cargo run --release --example isp_workloads`

use dockerssd::config::SystemConfig;
use dockerssd::etheron::TcpStack;
use dockerssd::firmware::{CostModel, Syscall, VirtualFw};
use dockerssd::lambdafs::{LambdaFs, LockSide};
use dockerssd::metrics::Table;
use dockerssd::models::{evaluate, ModelKind};
use dockerssd::ssd::SsdDevice;
use dockerssd::util::SimTime;
use dockerssd::workloads::{all_workloads, Op, TraceGenerator};

fn main() {
    let cfg = SystemConfig::default();
    let costs = CostModel::calibrated();

    // --- part 1: trace replay on the substrates --------------------------
    println!("replaying scaled traces on the simulated DockerSSD:");
    let mut t = Table::new(vec!["workload", "ops", "sim_time", "walk_cache_hit%", "icl_hit%"]);
    for spec in all_workloads() {
        let mut dev = SsdDevice::new(cfg.ssd.clone());
        let mut fs = LambdaFs::over_device(&dev);
        let mut fw = VirtualFw::new(&cfg.ssd);
        let mut tcp = TcpStack::new();
        tcp.listen(80);

        let scale = 2_000; // shrink Table 2 counts for a fast replay
        let ops = TraceGenerator::new(spec.clone(), 7, scale).generate();
        let mut now = SimTime::ZERO;
        // pre-create the file population
        let files = ops
            .iter()
            .filter_map(|o| match o {
                Op::Open { file } | Op::Read { file, .. } | Op::Write { file, .. } => Some(*file),
                _ => None,
            })
            .max()
            .unwrap_or(0)
            + 1;
        for f in 0..files {
            let _ = fs.write_file(&mut dev, now, &format!("/data/f{f}"), b"seed", LockSide::Isp);
        }
        for op in &ops {
            match op {
                Op::Open { file } => {
                    let _ = fs.walk(&format!("/data/f{file}"));
                    now += fw.syscall(Syscall::Openat);
                }
                Op::Read { file, bytes } => {
                    let path = format!("/data/f{file}");
                    if let Ok(r) = fs.read_file(&mut dev, now, &path, LockSide::Isp) {
                        now = r.done;
                    }
                    let _ = bytes;
                }
                Op::Write { file, bytes } => {
                    let path = format!("/data/f{file}");
                    let body = vec![7u8; (*bytes).min(65_536) as usize];
                    if let Ok(r) = fs.write_file(&mut dev, now, &path, &body, LockSide::Isp) {
                        now = r.done;
                    }
                }
                Op::Syscall => {
                    now += fw.syscall(Syscall::Futex);
                }
                Op::TcpPacket { .. } => {
                    now += SimTime::ns(costs.t_pkt_ethon_ns);
                }
                Op::Compute { bytes } => {
                    let ns = *bytes as f64
                        * costs.t_proc_host_ns_per_byte
                        * costs.ssd_compute_factor();
                    now += SimTime::ns(ns as u64);
                }
            }
        }
        let walks = fs.walk_cache.hits() + fs.walk_cache.misses();
        t.row(vec![
            spec.full_name(),
            format!("{}", ops.len()),
            format!("{now}"),
            format!("{:.0}%", 100.0 * fs.walk_cache.hits() as f64 / walks.max(1) as f64),
            format!("{:.0}%", 100.0 * dev.icl.hit_rate()),
        ]);
    }
    println!("{}", t.render());

    // --- part 2: the six models on all workloads (Fig 11 view) ------------
    println!("analytic model comparison (normalized to D-VirtFW):");
    let mut t = Table::new(vec!["workload", "Host", "P.ISP-R", "P.ISP-V", "D-Naive", "D-FullOS"]);
    for w in all_workloads() {
        let base = evaluate(ModelKind::DVirtFw, &w, &costs).total();
        t.row(vec![
            w.full_name(),
            format!("{:.2}", evaluate(ModelKind::Host, &w, &costs).total() / base),
            format!("{:.2}", evaluate(ModelKind::PIspR, &w, &costs).total() / base),
            format!("{:.2}", evaluate(ModelKind::PIspV, &w, &costs).total() / base),
            format!("{:.2}", evaluate(ModelKind::DNaive, &w, &costs).total() / base),
            format!("{:.2}", evaluate(ModelKind::DFullOs, &w, &costs).total() / base),
        ]);
    }
    println!("{}", t.render());
    println!("isp_workloads OK");
}
